"""Quickstart: EF21-Muon in ~40 lines.

Train a reduced Granite-3-2B on the synthetic corpus with 2 heterogeneous
workers, Top-10% + error feedback w2s compression, and a spectral-norm
LMO (= distributed compressed Muon).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.schedule import warmup_linear_decay
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_config("granite-3-2b").reduced()     # 2 layers, d=256 (CPU-sized)
model = build_model(cfg)

trainer = Trainer(model, TrainerConfig(
    n_workers=2,          # EF21 workers (pods / DP groups at scale)
    beta=0.5,             # momentum: M <- (1-b) M + b grad
    w2s="top10",          # worker->server compressor (EF21)
    s2w="identity",       # server->worker compressor (EF21-P off)
    use_pallas=False,     # CPU: use the jnp oracle for Newton-Schulz
    remat=False))

data = SyntheticLM(cfg, ShapeSpec("q", "train", seq=64, batch=8),
                   n_workers=2)
state = trainer.init(jax.random.key(0))
step = jax.jit(trainer.make_step())
radius = warmup_linear_decay(0.01, warmup=5, total=60)

wire = trainer.opt.w2s_bytes_per_worker(state["x"], trainer.metas)
dense = trainer.opt.dense_bytes(state["x"])
print(f"w2s payload: {wire / 1e3:.0f} kB/worker/step "
      f"({wire / dense:.2%} of dense)")

for i in range(60):
    state, aux = step(state, data.batch_at(i), radius(i))
    if i % 10 == 0 or i == 59:
        print(f"step {i:3d}  loss {float(aux['loss']):.3f}")
