"""Serve a small model with batched requests: prefill once, decode many.

Exercises the production decode path (ring/KV/recurrent caches) on three
different architecture families.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.models.api import build_model, make_batch
from repro.train.serve import Server

for arch in ("granite-3-2b", "recurrentgemma-2b", "xlstm-1.3b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    server = Server(model)
    batch = make_batch(cfg, ShapeSpec("s", "prefill", 24, 4),
                       jax.random.key(1))
    t0 = time.time()
    toks = server.generate(params, batch, max_new=12,
                           temperature=0.8, key=jax.random.key(2))
    dt = time.time() - t0
    print(f"{arch:20s} generated {toks.shape} in {dt:5.2f}s "
          f"({toks.size / dt:6.1f} tok/s)   first row: "
          f"{' '.join(str(int(t)) for t in toks[0][:8])}")
