"""End-to-end driver (paper §5 analogue): train a reduced NanoGPT for a
few hundred steps with EF21-Muon under three compression settings and
compare loss-vs-wire-bytes — the CPU-scale version of Figure 1.

    PYTHONPATH=src python examples/train_nanogpt_ef21.py [--steps 200]
"""
import argparse

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.schedule import warmup_linear_decay
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.train.checkpoint import save_checkpoint
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--checkpoint", default=None)
args = ap.parse_args()

cfg = get_config("nanogpt-124m").reduced()
model = build_model(cfg)
data = SyntheticLM(cfg, ShapeSpec("n", "train", 64, 16), n_workers=4)

for w2s in ("identity", "top15+natural", "rank15+natural"):
    tr = Trainer(model, TrainerConfig(n_workers=4, beta=0.7, w2s=w2s,
                                      remat=False, use_pallas=False))
    state = tr.init(jax.random.key(0))
    step = jax.jit(tr.make_step())
    sched = warmup_linear_decay(0.01, 10, args.steps, final_frac=0.3)
    wire = tr.opt.w2s_bytes_per_worker(state["x"], tr.metas)
    loss = None
    for i in range(args.steps):
        state, aux = step(state, data.batch_at(i), sched(i))
        loss = float(aux["loss"])
        if i % 25 == 0:
            print(f"[{w2s:16s}] step {i:3d} loss {loss:.3f} "
                  f"(sent {wire * (i + 1) / 1e6:.1f} MB/worker)")
    print(f"[{w2s:16s}] FINAL loss {loss:.3f} after "
          f"{wire * args.steps / 1e6:.1f} MB/worker w2s traffic")
    if args.checkpoint:
        save_checkpoint(f"{args.checkpoint}.{w2s}.npz", state, args.steps)
