"""Tour of the non-Euclidean compressor zoo (paper §D): empirical
contraction factors alpha w.r.t. different norms, wire cost, and the
"LMO as compressor" view (§D.1: the nuclear-norm sharp operator IS a
Rank-1 compressor).

    PYTHONPATH=src python examples/compressor_zoo.py
"""
import jax
import jax.numpy as jnp

from repro.core.compressors import (ColumnTopK, Natural, RandomDropout,
                                    RankK, TopK, TopKSVD, WithNatural,
                                    empirical_alpha)
from repro.core.lmo import sharp
from repro.core.norms import norm

key = jax.random.key(0)
x = jax.random.normal(key, (64, 48))

print(f"{'compressor':22s} {'norm':10s} {'alpha_emp':>9s} {'bytes':>8s}")
for comp, kind in [
        (TopK(0.1), "frobenius"),
        (TopKSVD(rank=4), "spectral"),
        (TopKSVD(rank=4), "nuclear"),
        (TopKSVD(rank=4), "frobenius"),
        (ColumnTopK(0.25), "col_l2_dual"),
        (Natural(), "frobenius"),
        (Natural(), "linf"),
        (RandomDropout(0.6), "frobenius"),
        (RankK(fraction=0.15), "frobenius"),
        (WithNatural(TopK(0.15)), "frobenius")]:
    a = empirical_alpha(comp, key, x, n_trials=4, norm_kind=kind)
    b = comp.payload_bytes(x.shape, jnp.bfloat16)
    print(f"{comp.name:22s} {kind:10s} {a:9.3f} {b:8d}")

# §D.1: the sharp operator of the nuclear norm is a Rank-1 compressor
gs = sharp(x, "nuclear")
res = float(norm(x - (-gs), "frobenius") / norm(x, "frobenius"))
print(f"\nnuclear-norm sharp operator as compressor: rank={int(jnp.linalg.matrix_rank(-gs))} "
      f"frobenius residual {res:.3f} (alpha ~ 1/rank(X))")
