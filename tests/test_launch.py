"""Launch-layer units: dry-run admissibility, roofline terms, zero-1 LMO
partition rule, head-padding adaptation, mesh helpers."""
import dataclasses

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.hlo_analysis import roofline_terms


def test_long500k_admissibility_matches_design():
    """Sub-quadratic gate: exactly the 4 archs with recurrent state or a
    sliding window run long_500k (DESIGN.md §Arch-applicability)."""
    runs = {a for a in ARCHS if a != "nanogpt-124m"
            and get_config(a).sub_quadratic}
    assert runs == {"xlstm-1.3b", "recurrentgemma-2b", "mixtral-8x7b",
                    "starcoder2-15b"}


def test_skip_reason():
    from repro.launch.dryrun import skip_reason
    assert skip_reason(get_config("granite-3-2b"),
                       SHAPES["long_500k"]) is not None
    assert skip_reason(get_config("granite-3-2b"),
                       SHAPES["train_4k"]) is None
    assert skip_reason(get_config("xlstm-1.3b"),
                       SHAPES["long_500k"]) is None


def test_roofline_terms_and_bottleneck():
    r = roofline_terms(197e12, 0.0, 0.0)
    assert r["bottleneck"] == "compute" and abs(r["t_compute_s"] - 1) < 1e-9
    r = roofline_terms(0.0, 819e9, 1.0)
    assert r["bottleneck"] == "memory"
    r = roofline_terms(1.0, 1.0, 50e9 * 2)
    assert r["bottleneck"] == "collective" and r["t_collective_s"] == 2.0


def test_model_flops_conventions():
    from repro.launch.dryrun import _model_flops
    cfg = get_config("granite-3-2b")
    tr = _model_flops(cfg, SHAPES["train_4k"], total=10, active=10)
    assert tr == 6.0 * 10 * 256 * 4096
    de = _model_flops(cfg, SHAPES["decode_32k"], total=10, active=7)
    assert de == 2.0 * 7 * 128  # one token per sequence, active params


def test_moe_active_params_counted():
    from repro.launch.dryrun import _abstract_params, _param_counts
    from repro.models.api import build_model
    cfg = get_config("mixtral-8x7b").reduced()
    model = build_model(cfg)
    shapes, metas = _abstract_params(model)
    total, active = _param_counts(cfg, shapes, metas)
    assert active < total  # top-2 of 4 experts in the reduced config
    frac = cfg.moe.top_k / cfg.moe.n_experts
    assert active >= total * frac * 0.5


def test_zero1_lmo_pspec_rule():
    from repro.core.muon import ParamMeta
    from repro.dist.sharding import state_pspecs
    from tests.test_sharding import FakeMesh

    class S:
        def __init__(self, shape, dtype="f"):
            self.shape = shape

    mesh = FakeMesh(data=16, model=16)
    params = {"w": S((32, 1024, 4096))}   # 32 layers: divisible by 16
    metas = {"w": ParamMeta("spectral", 1.0, 1)}
    state = {"step": S(()), "x": dict(params), "g_server": dict(params),
             "g_w": {"w": S((16, 32, 1024, 4096))}, "m_w": None,
             "cw_state": {}}
    sp = state_pspecs(state, params, metas, mesh, zero1_lmo=True)
    assert sp["x"]["w"][0] == "data"          # layer-parallel server state
    assert sp["g_w"]["w"][0] == "data"        # worker dim stays on workers
    # non-divisible stack: rule must not fire
    params2 = {"w": S((40, 1024, 4096))}
    state2 = dict(state, x=dict(params2), g_server=dict(params2),
                  g_w={"w": S((16, 40, 1024, 4096))})
    sp2 = state_pspecs(state2, params2, metas, mesh, zero1_lmo=True)
    assert sp2["x"]["w"][0] is None


def test_pad_heads_config_adaptation():
    """§Perf C2: the padded-head variant keeps head_dim and kv heads."""
    cfg = get_config("qwen2-vl-7b")
    padded = dataclasses.replace(cfg, n_heads=32, head_dim=cfg.hd)
    assert padded.hd == cfg.hd == 128
    assert padded.n_kv_heads == cfg.n_kv_heads
    assert padded.n_heads % 16 == 0


def test_make_batch_matches_input_specs(key):
    from repro.configs.base import ShapeSpec
    from repro.models.api import input_specs, make_batch
    cfg = get_config("whisper-small").reduced()
    sh = ShapeSpec("t", "train", 16, 4)
    specs = input_specs(cfg, sh, n_workers=2)
    batch = make_batch(cfg, sh, key, n_workers=2)
    assert set(specs) == set(batch)
    for k in specs:
        assert batch[k].shape == specs[k].shape
        assert batch[k].dtype == specs[k].dtype
