"""EF21-Muon special-case recovery (paper §3 "Role of Compression"):
identity compressors + n_workers=1 reduce EXACTLY to Gluon (=> Muon for
spectral norms, Scion for spectral+sign maps); beta=1 gives the
deterministic Algorithm 2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gluon import gluon_init, gluon_update
from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta


def _toy_problem(key):
    k1, k2, k3 = jax.random.split(key, 3)
    T = {"w": jax.random.normal(k1, (12, 8)),
         "v": jax.random.normal(k2, (16,))}
    metas = {"w": ParamMeta("spectral", 1.0, 0),
             "v": ParamMeta("sign", 1.0, 0)}
    params = {"w": jnp.zeros((12, 8)), "v": jnp.zeros((16,))}

    def loss(p):
        return (0.5 * jnp.sum((p["w"] - T["w"]) ** 2)
                + 0.5 * jnp.sum((p["v"] - T["v"]) ** 2))

    def grad_and_loss(p, batch):
        return loss(p), jax.grad(loss)(p)

    return params, metas, grad_and_loss, loss


def test_identity_single_worker_recovers_gluon(key):
    params, metas, gal, loss = _toy_problem(key)
    beta = 0.3

    opt = EF21Muon(EF21MuonConfig(n_workers=1, beta=beta, w2s="identity",
                                  use_pallas=False))
    state = opt.init(key, params, metas)
    step = opt.make_step(metas)

    gp = params
    gstate = gluon_init(params)
    batch = jnp.zeros((1, 1))
    for k in range(6):
        state, aux = step(state, gal, batch, 0.05)
        _, grads = gal(gp, None)
        gp, gstate = gluon_update(gp, grads, gstate, metas, 0.05, beta=beta,
                                  use_pallas=False)
        for name in ("w", "v"):
            np.testing.assert_allclose(np.asarray(state["x"][name]),
                                       np.asarray(gp[name]), rtol=1e-5,
                                       atol=1e-6)


def test_beta_one_is_deterministic_alg2(key):
    """beta = 1: momentum state vanishes and the method is Algorithm 2."""
    params, metas, gal, loss = _toy_problem(key)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, beta=1.0, w2s="identity",
                                  use_pallas=False))
    state = opt.init(key, params, metas)
    assert state["m_w"] is None
    step = opt.make_step(metas)
    batch = jnp.zeros((1, 1))
    l0 = float(loss(state["x"]))
    # LMO steps move a fixed radius t per step in the ball norm: the
    # spectral distance to the target is ~4-5, so budget 120 x 0.08
    for _ in range(120):
        state, aux = step(state, gal, batch, 0.08)
    assert float(loss(state["x"])) < 0.2 * l0


@pytest.mark.parametrize("w2s", ["top10", "rank10", "natural",
                                 "top15+natural"])
def test_compressed_multiworker_converges(w2s, key):
    """2 heterogeneous workers + biased compression + EF: still converges
    on the quadratic (the paper's whole point)."""
    k1, k2 = jax.random.split(key)
    T1 = jax.random.normal(k1, (16, 16))
    T2 = jax.random.normal(k2, (16, 16))
    metas = ParamMeta("spectral", 1.0, 0)
    params = jnp.zeros((16, 16))

    def gal(p, worker_batch):
        # worker identity is carried in the batch (0 or 1)
        t = jnp.where(worker_batch[0] > 0, T2, T1)
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    opt = EF21Muon(EF21MuonConfig(n_workers=2, beta=1.0, w2s=w2s,
                                  use_pallas=False))
    state = opt.init(key, params, metas)
    step = opt.make_step(metas)
    batch = jnp.array([[0.0], [1.0]])
    for k in range(120):
        state, aux = step(state, gal, batch, 0.05)
    opt_pt = 0.5 * (T1 + T2)  # minimiser of the average
    err = float(jnp.linalg.norm(state["x"] - opt_pt)
                / jnp.linalg.norm(opt_pt))
    assert err < 0.25, f"{w2s}: err {err}"


def test_wire_byte_accounting(key):
    params, metas, gal, _ = _toy_problem(key)
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False))
    dense = opt.dense_bytes(params)
    wire = opt.w2s_bytes_per_worker(params, metas)
    assert 0 < wire < dense
    opt_id = EF21Muon(EF21MuonConfig(n_workers=4, w2s="identity"))
    assert opt_id.w2s_bytes_per_worker(params, metas) == dense


def _hetero_quadratic(key, n_workers=4, dim=16):
    Ts = jax.random.normal(key, (n_workers, dim, dim))

    def gal(p, wb):
        t = Ts[jnp.int32(wb[0])]
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    metas = ParamMeta("spectral", 1.0, 0)
    params = jnp.zeros((dim, dim))
    batch = jnp.arange(float(n_workers)).reshape(n_workers, 1)
    return params, metas, gal, batch


def test_participation_full_bit_equal(key):
    """participation='full' (the default robustness-off arm) is VALUE-
    BIT-EQUAL to the pre-participation step: the elastic path is only
    built when something can actually mask (§11)."""
    params, metas, gal, batch = _hetero_quadratic(key)
    base = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                   use_pallas=False))
    full = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                   use_pallas=False, participation="full"))
    s_a = base.init(key, params, metas)
    s_b = full.init(key, params, metas)
    step_a = jax.jit(lambda s, b: base.make_step(metas)(s, gal, b, 0.05))
    step_b = jax.jit(lambda s, b: full.make_step(metas)(s, gal, b, 0.05))
    for _ in range(4):
        s_a, _ = step_a(s_a, batch)
        s_b, _ = step_b(s_b, batch)
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_frozen_worker_ef21_state(key):
    """A non-participating worker's EF21 error state e_t (g_w row),
    momentum and compressor state are BITWISE unchanged across the step
    (the Gluon-FL partial-participation contraction needs this), while
    participants' rows do move and the server fold uses the dynamic
    participant count."""
    from repro.dist.participation import Explicit
    params, metas, gal, batch = _hetero_quadratic(key)
    opt = EF21Muon(EF21MuonConfig(
        n_workers=4, beta=0.5, w2s="top10", use_pallas=False,
        participation=Explicit(((1, 1, 0, 1),))))  # worker 2 always out
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas)(s, gal, b, 0.05))
    # one warm step so g_w/m_w are non-trivial before the invariant check
    state, _ = step(state, batch)
    g_before = np.asarray(state["g_w"][2])
    m_before = np.asarray(state["m_w"][2])
    new, aux = step(state, batch)
    assert np.array_equal(np.asarray(new["g_w"][2]), g_before)
    assert np.array_equal(np.asarray(new["m_w"][2]), m_before)
    assert int(aux["n_participants"]) == 3
    assert not bool(aux["skipped"])
    # a participating worker's EF21 state does advance
    assert not np.array_equal(np.asarray(new["g_w"][0]),
                              np.asarray(state["g_w"][0]))


def test_ef21p_s2w_compression_runs(key):
    """Bidirectional: EF21-P model-shift compression (s2w) keeps W state
    and still converges."""
    params, metas, gal, loss = _toy_problem(key)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, beta=1.0, w2s="top15",
                                  s2w="natural", use_pallas=False))
    state = opt.init(key, params, metas)
    assert "w" in state and "cs_state" in state
    step = opt.make_step(metas)
    batch = jnp.zeros((1, 1))
    l0 = float(loss(state["x"]))
    for _ in range(80):
        state, aux = step(state, gal, batch, 0.03)
    assert float(loss(state["x"])) < 0.3 * l0
