"""The trip-count-aware HLO cost analyzer vs ground truth (unrolled
modules) — this is what makes the roofline numbers correct."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, parse_module, top_contributors


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    def f_scan(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def f_unroll(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = analyze(_text(f_scan, x, w))
    fu = analyze(_text(f_unroll, x, w))
    expected = 2 * 64 * 128 * 128 * 8
    assert fs["flops"] == expected
    assert fu["flops"] == expected
    # builtin cost_analysis undercounts the scan (the motivation)
    from repro.launch.hlo_cost import cost_analysis_dict
    builtin = cost_analysis_dict(jax.jit(f_scan).lower(x, w).compile())
    if "flops" not in builtin:
        pytest.skip("backend cost_analysis reports no flops")
    assert float(builtin["flops"]) < expected / 2


def test_nested_scan_flops():
    def g(x, w):
        def outer(x, wi):
            def inner(x, _):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(inner, x, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    a = analyze(_text(g, x, w))
    assert a["flops"] == 2 * 32 * 64 * 64 * 5 * 3


def test_hbm_bytes_scale_with_trips():
    def f(x):
        def body(x, _):
            return jnp.tanh(x) * 1.5 + x, None
        return jax.lax.scan(body, x, None, length=10)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a = analyze(_text(f, x))
    one = 256 * 256 * 4
    # ~2 materialisations per trip (read + write), 10 trips
    assert a["hbm_bytes"] > 10 * one
    assert a["hbm_bytes"] < 100 * one


def test_dus_counted_in_place():
    def f(buf, x):
        def body(buf, i):
            return jax.lax.dynamic_update_slice(buf, x, (i, 0)), None
        return jax.lax.scan(body, buf, jnp.arange(50))[0]

    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    a = analyze(_text(f, buf, x))
    # in-place model: 50 x ~4KB, NOT 50 x 4MB
    assert a["hbm_bytes"] < 50 * 1024 * 1024


def test_parse_module_structure():
    def f(x):
        return jnp.sum(x @ x.T)
    txt = _text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    comps = parse_module(txt)
    assert any(n.startswith("main") for n in comps)
    a = analyze(txt)
    assert a["flops"] >= 2 * 32 * 32 * 32


# ------------------------------------------- async-collective pair parsing
#
# Hand-written HLO: the all-gather-start/done pairing and overlap
# attribution must not depend on what the local backend emits (the CPU
# backend never splits collectives — there the analyzer synthesises
# pairs from the dependence cone, covered further down).

ASYNC_FLAT = """
HloModule m, is_scheduled=true

ENTRY %main (p0: f32[1,256], w: f32[128,128]) -> f32[128,128] {
  %p0 = f32[1,256] parameter(0)
  %w = f32[128,128] parameter(1)
  %ag-start = (f32[1,256], f32[8,256]) all-gather-start(f32[1,256] %p0), dimensions={0}
  %dot1 = f32[128,128] dot(f32[128,128] %w, f32[128,128] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag-done = f32[8,256] all-gather-done((f32[1,256], f32[8,256]) %ag-start)
  %dot2 = f32[128,128] dot(f32[128,128] %dot1, f32[128,128] %dot1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[128,128] add(f32[128,128] %dot2, f32[128,128] %dot1)
}
"""

DOT_FLOPS = 2 * 128 * 128 * 128


def test_async_pair_attributes_scheduled_window():
    """An all-gather-start/done pair hides exactly the FLOPs scheduled
    between start and done — dot1 (in the window), not dot2 (after)."""
    a = analyze(ASYNC_FLAT)
    assert a["coll_bytes"] == 1 * 256 * 4        # start operand, not -done
    assert a["flops"] == 2 * DOT_FLOPS
    (p,) = a["coll_pairs"]
    assert p["kind"] == "all-gather" and p["count"] == 1.0
    assert p["bytes"] == 1 * 256 * 4 and not p["u8"]
    assert p["overlap_flops"] == DOT_FLOPS


ASYNC_WHILE = """
HloModule m, is_scheduled=true

%cond (pc: (s32[], f32[1,256], f32[128,128])) -> pred[] {
  %pc = (s32[], f32[1,256], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[1,256], f32[128,128]) %pc), index=0
  %trip = s32[] constant(6)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %trip), direction=LT
}

%body (pb: (s32[], f32[1,256], f32[128,128])) -> (s32[], f32[1,256], f32[128,128]) {
  %pb = (s32[], f32[1,256], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[1,256], f32[128,128]) %pb), index=0
  %x = f32[1,256] get-tuple-element((s32[], f32[1,256], f32[128,128]) %pb), index=1
  %w = f32[128,128] get-tuple-element((s32[], f32[1,256], f32[128,128]) %pb), index=2
  %ag-start.1 = (f32[1,256], f32[8,256]) all-gather-start(f32[1,256] %x), dimensions={0}
  %dotb = f32[128,128] dot(f32[128,128] %w, f32[128,128] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag-done.1 = f32[8,256] all-gather-done((f32[1,256], f32[8,256]) %ag-start.1)
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  %xs = f32[1,256] slice(f32[8,256] %ag-done.1), slice={[0:1], [0:256]}
  ROOT %tup = (s32[], f32[1,256], f32[128,128]) tuple(s32[] %ip, f32[1,256] %xs, f32[128,128] %dotb)
}

ENTRY %main (p: (s32[], f32[1,256], f32[128,128])) -> (s32[], f32[1,256], f32[128,128]) {
  %p = (s32[], f32[1,256], f32[128,128]) parameter(0)
  ROOT %loop = (s32[], f32[1,256], f32[128,128]) while((s32[], f32[1,256], f32[128,128]) %p), condition=%cond, body=%body
}
"""


def test_async_pair_in_while_body_scales_with_trips():
    """A start/done pair inside a while body keeps per-occurrence bytes
    and overlap FLOPs with count = trip count — so both the paired bytes
    (count x bytes == coll_bytes) and the attributed compute stay
    consistent with the trip-count-aware totals."""
    a = analyze(ASYNC_WHILE)
    assert a["flops"] == 6 * DOT_FLOPS
    assert a["coll_bytes"] == 6 * 1024
    (p,) = a["coll_pairs"]
    assert p["count"] == 6.0
    assert p["bytes"] == 1024 and p["overlap_flops"] == DOT_FLOPS
    assert p["count"] * p["bytes"] == a["coll_bytes"]


SYNC_DEPS = """
HloModule m, is_scheduled=true

ENTRY %main (a: u8[1,256], w: f32[128,128]) -> f32[128,128] {
  %a = u8[1,256] parameter(0)
  %w = f32[128,128] parameter(1)
  %pre = u8[1,256] add(u8[1,256] %a, u8[1,256] %a)
  %ag = u8[8,256] all-gather(u8[1,256] %pre), dimensions={0}
  %ind = f32[128,128] dot(f32[128,128] %w, f32[128,128] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cvt = f32[8,256] convert(u8[8,256] %ag)
  %red = f32[128,128] dot(f32[8,256] %cvt, f32[8,256] %cvt), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT %out = f32[128,128] add(f32[128,128] %ind, f32[128,128] %red)
}
"""


def test_sync_pair_attributes_dependence_cone():
    """A sync collective (CPU text) hides the FLOPs outside its
    dependence cone: the independent dot counts, the dot consuming the
    gathered bytes (descendant) does not — and the u8 flag survives."""
    a = analyze(SYNC_DEPS)
    (p,) = a["coll_pairs"]
    assert p["kind"] == "all-gather" and p["u8"]
    assert p["bytes"] == 256 and p["count"] == 1.0
    assert p["overlap_flops"] == DOT_FLOPS     # %ind only, never %red


def test_exposed_collective_terms_floor_and_unpaired():
    """Roofline side: per-pair exposure floors at zero, unpaired bytes
    stay fully exposed, and full overlap drives the term to zero."""
    from repro.launch.hlo_analysis import (exposed_collective_terms,
                                           overlap_roofline_terms)
    pk, bw = 100.0, 10.0                # 1 FLOP == 0.01 s, 1 B == 0.1 s
    pairs = [{"kind": "all-gather", "bytes": 4.0, "u8": True,
              "overlap_flops": 1000.0, "count": 1.0},   # fully hidden
             {"kind": "all-gather", "bytes": 2.0, "u8": True,
              "overlap_flops": 10.0, "count": 2.0}]     # 0.2s - 0.1s each
    t = exposed_collective_terms(pairs, coll_bytes=18.0,
                                 peak_flops=pk, ici_bw=bw)
    # 2 x (0.2 - 0.1) exposed + (18 - 8) unpaired bytes / bw
    assert abs(t["t_exposed_collective_s"] - (0.2 + 1.0)) < 1e-12
    assert t["paired_coll_bytes"] == 8
    full = overlap_roofline_terms(1.0, 0.0, 8.0, pairs[:1],
                                  peak_flops=pk, hbm_bw=1.0, ici_bw=bw)
    # the one pair covers half the bytes; the other half stays exposed
    assert abs(full["t_exposed_collective_s"] - 0.4) < 1e-12
    assert full["t_collective_s"] == 0.8
    assert full["bottleneck_overlap"] == "collective"


def test_attribute_u8_directions_quota_matching():
    """Per-direction u8 attribution (§9): quota-based multiset matching
    stays exact on size collisions between the two directions, scales
    with pair counts, ignores non-u8 pairs, and reports unmatched /
    missing multisets."""
    from repro.launch.hlo_analysis import attribute_u8_directions

    def pair(b, u8=True, count=1.0, kind="all-gather"):
        return {"kind": kind, "bytes": float(b), "u8": u8,
                "overlap_flops": 0.0, "count": count}

    # clean two-direction case, with a size both directions expect (100):
    # quota resolves the collision — one 100 to each direction
    split = attribute_u8_directions(
        [pair(100), pair(100), pair(30), pair(70), pair(50, u8=False)],
        w2s_sizes=[100, 30], s2w_sizes=[100, 70])
    assert split["w2s"] == {"bytes": 130, "count": 2}
    assert split["s2w"] == {"bytes": 170, "count": 2}
    assert split["unmatched_bytes"] == [] and split["missing"] == {}
    # count-scaled pairs (while-body collectives) consume one quota per
    # occurrence; surplus occurrences land in unmatched
    split = attribute_u8_directions([pair(10, count=3.0)],
                                    w2s_sizes=[10, 10], s2w_sizes=[])
    assert split["w2s"] == {"bytes": 20, "count": 2}
    assert split["unmatched_bytes"] == [10]
    # expected-but-never-seen sizes surface per direction, as multisets
    split = attribute_u8_directions([pair(8)], w2s_sizes=[8, 9, 9],
                                    s2w_sizes=[4])
    assert split["w2s"] == {"bytes": 8, "count": 1}
    assert split["s2w"] == {"bytes": 0, "count": 0}
    assert split["missing"] == {"w2s": [9, 9], "s2w": [4]}
    # empty expectations: every u8 pair is unmatched
    split = attribute_u8_directions([pair(5)], w2s_sizes=[], s2w_sizes=[])
    assert split["unmatched_bytes"] == [5]
    assert split["w2s"]["count"] == 0 and split["s2w"]["count"] == 0


def test_top_contributors_consistent_with_total():
    def f_scan(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    txt = _text(f_scan, x, w)
    total = analyze(txt)["flops"]
    rows = top_contributors(txt, 1000, key="flops")
    np.testing.assert_allclose(sum(r[0] for r in rows), total, rtol=1e-6)
