"""The trip-count-aware HLO cost analyzer vs ground truth (unrolled
modules) — this is what makes the roofline numbers correct."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, parse_module, top_contributors


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    def f_scan(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def f_unroll(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = analyze(_text(f_scan, x, w))
    fu = analyze(_text(f_unroll, x, w))
    expected = 2 * 64 * 128 * 128 * 8
    assert fs["flops"] == expected
    assert fu["flops"] == expected
    # builtin cost_analysis undercounts the scan (the motivation)
    from repro.launch.hlo_cost import cost_analysis_dict
    builtin = cost_analysis_dict(jax.jit(f_scan).lower(x, w).compile())
    if "flops" not in builtin:
        pytest.skip("backend cost_analysis reports no flops")
    assert float(builtin["flops"]) < expected / 2


def test_nested_scan_flops():
    def g(x, w):
        def outer(x, wi):
            def inner(x, _):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(inner, x, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    a = analyze(_text(g, x, w))
    assert a["flops"] == 2 * 32 * 64 * 64 * 5 * 3


def test_hbm_bytes_scale_with_trips():
    def f(x):
        def body(x, _):
            return jnp.tanh(x) * 1.5 + x, None
        return jax.lax.scan(body, x, None, length=10)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a = analyze(_text(f, x))
    one = 256 * 256 * 4
    # ~2 materialisations per trip (read + write), 10 trips
    assert a["hbm_bytes"] > 10 * one
    assert a["hbm_bytes"] < 100 * one


def test_dus_counted_in_place():
    def f(buf, x):
        def body(buf, i):
            return jax.lax.dynamic_update_slice(buf, x, (i, 0)), None
        return jax.lax.scan(body, buf, jnp.arange(50))[0]

    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    a = analyze(_text(f, buf, x))
    # in-place model: 50 x ~4KB, NOT 50 x 4MB
    assert a["hbm_bytes"] < 50 * 1024 * 1024


def test_parse_module_structure():
    def f(x):
        return jnp.sum(x @ x.T)
    txt = _text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    comps = parse_module(txt)
    assert any(n.startswith("main") for n in comps)
    a = analyze(txt)
    assert a["flops"] >= 2 * 32 * 32 * 32


def test_top_contributors_consistent_with_total():
    def f_scan(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    txt = _text(f_scan, x, w)
    total = analyze(txt)["flops"]
    rows = top_contributors(txt, 1000, key="flops")
    np.testing.assert_allclose(sum(r[0] for r in rows), total, rtol=1e-6)
