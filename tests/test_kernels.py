"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py, executed with interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# property tests skip individually when hypothesis is absent; the
# plain oracle tests in this file still run (see _hypothesis_compat)
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.newton_schulz import (fused_matmul, ns_iteration_fused,
                                         ns_iteration_pallas)
from repro.kernels.ops import natural_compress, natural_decompress, \
    newton_schulz, newton_schulz_batched


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 128), (384, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matmul_matches_ref(m, k, n, dtype, key):
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    c = jax.random.normal(k3, (m, n), dtype)
    got = fused_matmul(a, b, c=c, alpha=0.7, beta=1.3,
                       out_dtype=jnp.float32, interpret=True)
    want = ref.fused_matmul_ref(a, b, c, alpha=0.7, beta=1.3)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol,
                               atol=tol * 10)


def test_fused_matmul_no_c(key):
    a = jax.random.normal(key, (128, 128))
    b = jax.random.normal(jax.random.fold_in(key, 1), (128, 128))
    got = fused_matmul(a, b, interpret=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.fused_matmul_ref(a, b, None)),
                               rtol=1e-5)


def test_ns_iteration_matches_ref(key):
    x = jax.random.normal(key, (128, 256), jnp.float32) * 0.05
    got = ns_iteration_pallas(x, ref.NS_COEFFS, interpret=True)
    want = ref.ns_iteration_ref(x, ref.NS_COEFFS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 48), (48, 64), (200, 120),
                                   (128, 128), (13, 77)])
def test_newton_schulz_pallas_vs_oracle(shape, key):
    """Pallas path (zero-padded to 128 blocks) == jnp oracle, any shape."""
    g = jax.random.normal(key, shape, jnp.float32)
    got = newton_schulz(g, steps=5, use_pallas=True, interpret=True)
    want = ref.newton_schulz_ref(g, steps=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("bsz,m,n", [(2, 128, 256), (3, 256, 128),
                                     (1, 384, 384), (2, 256, 640)])
def test_ns_iteration_fused_matches_batched_ref(bsz, m, n, key):
    """ONE fused pallas_call (gram + poly + update in VMEM, symmetric
    gram tiles skipped) == the batched jnp iteration, multi-tile m
    included (exercises the triangular accumulate + mirror)."""
    x = jax.random.normal(key, (bsz, m, n), jnp.float32) * 0.05
    got = ns_iteration_fused(x, ref.NS_COEFFS, interpret=True)
    want = ref.ns_iteration_batched_ref(x, ref.NS_COEFFS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_ns_iteration_fused_bf16(key):
    x = (jax.random.normal(key, (2, 128, 128)) * 0.05).astype(jnp.bfloat16)
    got = ns_iteration_fused(x, ref.NS_COEFFS, interpret=True)
    want = ref.ns_iteration_batched_ref(x, ref.NS_COEFFS)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bsz,m,n", [(3, 96, 160), (2, 200, 120),
                                     (1, 128, 128), (4, 13, 77)])
def test_newton_schulz_batched_pallas_vs_oracle(bsz, m, n, key):
    """Batched Pallas path (zero-padded to 128 blocks, fused iteration)
    == batched jnp oracle, any slice shape."""
    g = jax.random.normal(key, (bsz, m, n), jnp.float32)
    got = newton_schulz_batched(g, steps=5, use_pallas=True, interpret=True)
    want = ref.newton_schulz_batched_ref(g, steps=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_newton_schulz_batched_unfused_fallback(key):
    """fused=False (the VMEM-infeasible fallback: vmapped three-call
    chain) computes the same batched result."""
    g = jax.random.normal(key, (2, 96, 160), jnp.float32)
    got = newton_schulz_batched(g, steps=3, use_pallas=True, interpret=True,
                                fused=False)
    want = ref.newton_schulz_batched_ref(g, steps=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_newton_schulz_fused_equals_chain(key):
    """The fused iteration and the three-call chain are the same
    algorithm: 2-D entry point, both pallas variants vs each other."""
    g = jax.random.normal(key, (100, 60), jnp.float32)
    a = newton_schulz(g, steps=3, use_pallas=True, interpret=True,
                      fused=True)
    b = newton_schulz(g, steps=3, use_pallas=True, interpret=True,
                      fused=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_newton_schulz_orthogonalises(key):
    g = jax.random.normal(key, (96, 160), jnp.float32)
    z = newton_schulz(g, steps=9, use_pallas=True, interpret=True)
    s = jnp.linalg.svd(z.astype(jnp.float32), compute_uv=False)
    # quintic NS keeps singular values in a band around 1, not exactly 1
    assert float(jnp.max(s)) < 1.3 and float(jnp.min(s)) > 0.6


@given(n=st.integers(1, 2000), seed=st.integers(0, 2 ** 16),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
@settings(max_examples=12, deadline=None)
def test_natural_roundtrip_property(n, seed, scale):
    """Hypothesis sweep: natural compress/decompress keeps relative error
    <= 1/3 for arbitrary lengths (incl. non-multiple-of-8)."""
    x = (jax.random.normal(jax.random.key(seed), (n,)) * scale
         ).astype(jnp.bfloat16)
    code, signs = natural_compress(x, use_pallas=False)
    xh = np.asarray(natural_decompress(code, signs, (n,), jnp.float32))
    xb = np.asarray(x, np.float32)
    nz = np.abs(xb) > 0
    rel = np.abs(xh[nz] - xb[nz]) / np.abs(xb[nz])
    assert rel.max() <= 1 / 3 + 1e-2 if nz.any() else True
    assert (xh[~nz] == 0).all()


@pytest.mark.parametrize("rows,cols", [(256, 128), (512, 256), (256, 384)])
def test_natural_pallas_kernel_matches_ref(rows, cols, key):
    from repro.kernels.natural_pack import natural_encode
    x = (jax.random.normal(key, (rows, cols)) *
         jnp.exp(jax.random.normal(jax.random.fold_in(key, 1),
                                   (rows, cols)) * 4)).astype(jnp.bfloat16)
    code_k, sign_k = natural_encode(x, block_rows=256, interpret=True)
    code_r, sign_r = ref.natural_compress_ref(x.reshape(-1))
    np.testing.assert_array_equal(np.asarray(code_k).reshape(-1),
                                  np.asarray(code_r))
    np.testing.assert_array_equal(np.asarray(sign_k).reshape(-1),
                                  np.asarray(sign_r))


def test_natural_pallas_end_to_end(key):
    """ops.natural_compress with the Pallas path (interpret) == ref path."""
    x = jax.random.normal(key, (1000,)).astype(jnp.bfloat16)
    c1, s1 = natural_compress(x, use_pallas=True, interpret=True)
    c2, s2 = natural_compress(x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@given(n8=st.integers(1, 1500), seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_bitpack_sign_kernels_match_refs(n8, seed):
    """Pallas 1-bit pack/unpack (interpret) == jnp refs, byte-for-byte,
    for arbitrary multiple-of-8 lengths (the Natural sign-plane path)."""
    from repro.kernels import bitpack as bp
    bits = jax.random.bernoulli(
        jax.random.key(seed), 0.5, (8 * n8,)).astype(jnp.uint8)
    ref_p = bp.pack_bits_ref(bits)
    ker_p = bp.pack_bits(bits, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref_p), np.asarray(ker_p))
    ker_u = bp.unpack_bits(ref_p, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(ker_u))


@pytest.mark.parametrize("width,hi", [(2, 1 << 16), (3, 1 << 24),
                                      (4, 1 << 24)])
@pytest.mark.parametrize("k", [1, 7, 128, 1000])
def test_bitpack_narrow_kernels_match_refs(width, hi, k, key):
    """Pallas narrow int encode/decode (interpret) == jnp refs and
    round-trip exactly for every supported byte width."""
    from repro.kernels import bitpack as bp
    idx = jax.random.randint(key, (k,), 0, hi, jnp.int32)
    ref_e = bp.narrow_encode_ref(idx, width)
    ker_e = bp.narrow_encode(idx, width, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref_e), np.asarray(ker_e))
    ker_d = bp.narrow_decode(ref_e, width, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ker_d))


def test_natural_compress_pallas_signs_roundtrip(key):
    """natural_compress with the full Pallas path (encode kernel + sign
    bitpack kernel) stays bit-identical to the jnp path end-to-end."""
    x = jax.random.normal(key, (777,)).astype(jnp.bfloat16)
    c1, s1 = natural_compress(x, use_pallas=True, interpret=True)
    c2, s2 = natural_compress(x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    x1 = natural_decompress(c1, s1, (777,), use_pallas=True, interpret=True)
    x2 = natural_decompress(c2, s2, (777,), use_pallas=False)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


def test_newton_schulz_errors_without_compiler_params(key, monkeypatch):
    """Neither CompilerParams nor TPUCompilerParams -> an explicit error
    (not a None crash) on the Pallas path; the jnp path keeps working."""
    import importlib
    ns_mod = importlib.import_module("repro.kernels.newton_schulz")
    g = jax.random.normal(key, (16, 16))
    monkeypatch.setattr(ns_mod, "_CompilerParams", None)
    with pytest.raises(RuntimeError, match="CompilerParams"):
        ns_mod.fused_matmul(jnp.zeros((128, 128)), jnp.zeros((128, 128)))
    out = newton_schulz(g, steps=2, use_pallas=False)
    assert out.shape == g.shape


def test_ns_zero_padding_exactness(key):
    """Zero padding is exact for NS: padded result sliced back equals the
    unpadded oracle (the ops.py wrapper invariant)."""
    g = jax.random.normal(key, (100, 60), jnp.float32)
    got = newton_schulz(g, steps=3, use_pallas=True, interpret=True,
                        block=128)
    want = ref.newton_schulz_ref(g, steps=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)
