"""Norm/dual-norm/LMO/sharp-operator identities (paper §2, §C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# property tests skip individually when hypothesis is absent; the
# plain oracle tests in this file still run (see _hypothesis_compat)
from _hypothesis_compat import given, settings, st

from repro.core.lmo import lmo_direction, lmo_step, sharp
from repro.core.norms import DUAL, dual_norm, norm, norm_equivalence_constants

KINDS_VEC = ["frobenius", "linf", "l1"]
KINDS_MAT = ["frobenius", "linf", "l1", "spectral", "nuclear", "col_l2",
             "row_l2"]
LMO_KINDS = {"spectral": "spectral", "sign": "linf", "euclid": "frobenius",
             "col_l2": "col_l2", "row_l2": "row_l2", "nuclear": "nuclear"}


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("kind", KINDS_MAT)
def test_norm_positive_homogeneous(kind, key):
    x = _rand(key, (6, 9))
    n1 = norm(x, kind)
    assert float(n1) > 0
    np.testing.assert_allclose(float(norm(2.5 * x, kind)), 2.5 * float(n1),
                               rtol=1e-5)
    np.testing.assert_allclose(float(norm(-x, kind)), float(n1), rtol=1e-5)


@pytest.mark.parametrize("kind", KINDS_MAT)
def test_triangle_inequality(kind, key):
    k1, k2 = jax.random.split(key)
    x, y = _rand(k1, (5, 7)), _rand(k2, (5, 7))
    assert float(norm(x + y, kind)) <= float(norm(x, kind)
                                             + norm(y, kind)) + 1e-4


@pytest.mark.parametrize("kind", KINDS_MAT)
def test_duality_pairing(kind, key):
    """<x, y> <= ||x|| * ||y||_* (generalised Cauchy-Schwarz)."""
    k1, k2 = jax.random.split(key)
    x, y = _rand(k1, (5, 7)), _rand(k2, (5, 7))
    lhs = float(jnp.sum(x * y))
    rhs = float(norm(x, kind)) * float(dual_norm(y, kind))
    assert lhs <= rhs + 1e-4


def test_dual_is_involutive():
    for k, d in DUAL.items():
        assert DUAL[d] == k


@pytest.mark.parametrize("lmo_kind,ball_norm", list(LMO_KINDS.items()))
def test_lmo_properties(lmo_kind, ball_norm, key):
    """LMO over the unit ball: ||Z*|| <= 1 and <g, Z*> = -||g||_*."""
    g = _rand(key, (8, 12))
    z = lmo_direction(g, lmo_kind, use_pallas=False)
    # Muon's quintic NS targets singular values in a ~[0.7, 1.2] band, not
    # exactly 1 (Jordan et al. 2024) — the ball constraint is approximate
    slack = 0.25 if lmo_kind in ("spectral", "nuclear") else 2e-2
    assert float(norm(z, ball_norm)) <= 1.0 + slack
    inner = float(jnp.sum(g * z))
    gstar = float(dual_norm(g, ball_norm))
    rtol = 0.2 if lmo_kind in ("spectral", "nuclear") else 1e-3
    np.testing.assert_allclose(inner, -gstar, rtol=rtol)


def test_sharp_operator_identities(key):
    """||g||_* = ||g#|| and <g, g#> = ||g#||^2 (paper §C) — exact kinds."""
    g = _rand(key, (8, 12))
    for kind, ball in (("sign", "linf"), ("euclid", "frobenius"),
                       ("col_l2", "col_l2"), ("row_l2", "row_l2")):
        gs = sharp(g, kind, use_pallas=False)
        np.testing.assert_allclose(float(dual_norm(g, ball)),
                                   float(norm(gs, ball)), rtol=1e-4)
        np.testing.assert_allclose(float(jnp.sum(g * gs)),
                                   float(norm(gs, ball)) ** 2, rtol=1e-3)


def test_lmo_step_moves_by_radius(key):
    g = _rand(key, (8, 8))
    x = _rand(jax.random.fold_in(key, 1), (8, 8))
    for kind, ball in (("sign", "linf"), ("euclid", "frobenius")):
        x2 = lmo_step(x, g, 0.37, kind, use_pallas=False)
        np.testing.assert_allclose(float(norm(x2 - x, ball)), 0.37,
                                   rtol=1e-4)


@given(m=st.integers(2, 12), n=st.integers(2, 12),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_norm_equivalence_property(m, n, seed):
    """rho_lo * ||X||_k <= ||X||_2 <= rho_hi * ||X||_k for random X."""
    x = jax.random.normal(jax.random.key(seed), (m, n))
    f = float(norm(x, "frobenius"))
    for kind in ("spectral", "linf", "l1", "col_l2", "row_l2"):
        lo, hi = norm_equivalence_constants((m, n), kind)
        nk = float(norm(x, kind))
        assert lo * nk <= f * (1 + 1e-5)
        assert f <= hi * nk * (1 + 1e-5)


def test_spectral_lmo_orthogonal(key):
    """Spectral LMO direction ~ -UV^T: singular values ~ 1."""
    g = _rand(key, (16, 24))
    z = lmo_direction(g, "spectral", ns_steps=9, use_pallas=False)
    s = jnp.linalg.svd(z.astype(jnp.float32), compute_uv=False)
    # quintic NS band, not exact orthogonality
    assert float(jnp.max(s)) < 1.3 and float(jnp.min(s)) > 0.6
