"""Error-feedback algebra + the paper's motivating divergence example.

The EF-necessity experiment (§2 "Error Feedback", Beznosikov et al.
Example 1): naive biased compression of gradients diverges on an average
of quadratics, while the EF21 mechanism converges.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import TopK, get_compressor
from repro.core.error_feedback import apply_payload, ef_compress_step


def test_ef_state_bit_consistency(key):
    """Sender and receiver estimates stay identical (the EF21 invariant)."""
    comp = TopK(0.2)
    target = jax.random.normal(key, (12, 12))
    est_send = jnp.zeros((12, 12))
    est_recv = jnp.zeros((12, 12))
    state = comp.init(key, target.shape, jnp.float32)
    for i in range(5):
        payload, state, est_send = ef_compress_step(comp, state, est_send,
                                                    target, jnp.float32)
        est_recv = apply_payload(comp, payload, est_recv)
        np.testing.assert_array_equal(np.asarray(est_send),
                                      np.asarray(est_recv))


def test_ef_estimate_converges_to_fixed_target(key):
    """Repeated EF rounds on a fixed target: ||G - T|| -> 0 geometrically
    (contraction factor sqrt(1 - alpha))."""
    comp = TopK(0.25)
    target = jax.random.normal(key, (20, 20))
    est = jnp.zeros_like(target)
    state = comp.init(key, target.shape, jnp.float32)
    errs = []
    for i in range(30):
        _, state, est = ef_compress_step(comp, state, est, target,
                                         jnp.float32)
        errs.append(float(jnp.linalg.norm(est - target)))
    assert errs[-1] < 1e-3 * errs[0]


def _quadratic_problem():
    """Average of 3 strongly convex quadratics with conflicting gradients
    (the divergence construction of Beznosikov et al. 2020, Example 1)."""
    a = jnp.array([[-3.0, 2.0, 2.0], [2.0, -3.0, 2.0], [2.0, 2.0, -3.0]])

    # f_j(x) = 0.5 x^T (I + e_j e_j^T) x + <a_j, x>; grads differ strongly
    def grad_j(x, j):
        return x + jnp.eye(3)[j] * x[j] + a[j]

    return grad_j


def test_biased_compression_without_ef_fails(key):
    """Top1-compressed gradient descent (no EF) stalls/diverges on the
    quadratic example while EF21 converges to the optimum."""
    grad_j = _quadratic_problem()
    comp = TopK(0.34)  # top-1 of 3
    lr = 0.1

    def naive(x0, steps=300):
        x = x0
        for _ in range(steps):
            g = jnp.mean(jnp.stack([
                comp.decompress(comp.compress({}, grad_j(x, j))[0],
                                (3,), jnp.float32) for j in range(3)]), 0)
            x = x - lr * g
        return x

    def ef21(x0, steps=300):
        x = x0
        G = [jnp.zeros(3)] * 3
        for _ in range(steps):
            for j in range(3):
                _, _, G[j] = ef_compress_step(comp, {}, G[j], grad_j(x, j),
                                              jnp.float32)
            x = x - lr * jnp.mean(jnp.stack(G), 0)
        return x

    x0 = jnp.array([1.0, 0.7, -0.3])
    # optimum: grad f(x*) = 0 for f = mean f_j
    def full_grad(x):
        return jnp.mean(jnp.stack([grad_j(x, j) for j in range(3)]), 0)

    x_naive = naive(x0)
    x_ef = ef21(x0)
    gn_naive = float(jnp.linalg.norm(full_grad(x_naive)))
    gn_ef = float(jnp.linalg.norm(full_grad(x_ef)))
    assert gn_ef < 1e-3, f"EF21 should converge, got grad norm {gn_ef}"
    assert gn_naive > 10 * gn_ef, \
        f"naive compression should stall: {gn_naive} vs {gn_ef}"


@pytest.mark.parametrize("name", ["identity", "natural", "top10",
                                  "top10+natural", "rank10",
                                  "rank10+natural"])
def test_apply_payload_matches_sender_estimate(name, key):
    """§2 invariant: the receiver's ``apply_payload(comp, payload, E)``
    must be *bit-identical* to the ``new_estimate`` the sender computed in
    ``ef_compress_step`` — the whole point of transmitting C(T - E) is
    that both sides advance E by the exact same decompressed message."""
    comp = get_compressor(name)
    shape = (24, 16)
    target = jax.random.normal(key, shape, jnp.float32)
    est_send = jnp.zeros(shape, jnp.float32)
    est_recv = jnp.zeros(shape, jnp.float32)
    state = comp.init(key, shape, jnp.dtype(jnp.bfloat16))
    for i in range(4):
        payload, state, est_send = ef_compress_step(comp, state, est_send,
                                                    target)
        est_recv = apply_payload(comp, payload, est_recv)
        np.testing.assert_array_equal(np.asarray(est_send),
                                      np.asarray(est_recv))


@pytest.mark.parametrize("name", ["top10", "rank10", "natural"])
def test_apply_payload_matches_sender_on_stacked_leaf(name, key):
    """Same invariant on a stacked leaf [L, m, n]: both sides vmapped over
    the stack dim, exactly as LayerPlan drives the optimizer phases."""
    L, shape = 3, (12, 8)
    target = jax.random.normal(key, (L,) + shape, jnp.float32)
    comp = get_compressor(name)
    keys = jax.random.split(key, L)
    state = jax.vmap(
        lambda k: comp.init(k, shape, jnp.dtype(jnp.bfloat16)))(keys)
    est_send = jnp.zeros((L,) + shape, jnp.float32)
    est_recv = jnp.zeros((L,) + shape, jnp.float32)

    def send(cs, e, t):
        return ef_compress_step(comp, cs, e, t)

    def recv(pl, e):
        return apply_payload(comp, pl, e)

    for i in range(3):
        payload, state, est_send = jax.vmap(send)(state, est_send, target)
        est_recv = jax.vmap(recv)(payload, est_recv)
        np.testing.assert_array_equal(np.asarray(est_send),
                                      np.asarray(est_recv))


def test_rank_fallback_is_deterministic_and_wrapped():
    """The documented resolve rule: rank-type compressors on non-2D
    slices fall back to TopK(0.25), preserving a requested Natural
    wrapper — never silently switching compression family by name."""
    from repro.core import compressors as C
    from repro.dist.layerwise import resolve_compressor

    assert isinstance(resolve_compressor("rank10", (128,)), C.TopK)
    fb = resolve_compressor("rank10+natural", (128,))
    assert isinstance(fb, C.WithNatural) and isinstance(fb.inner, C.TopK)
    # 2-D slices keep exactly what was asked for
    assert isinstance(resolve_compressor("rank10", (64, 32)), C.RankK)
    # non-rank compressors pass through on any shape
    assert isinstance(resolve_compressor("top10", (128,)), C.TopK)
    assert isinstance(resolve_compressor("natural", (128,)), C.Natural)


def test_identity_compressor_ef_is_exact(key):
    comp = get_compressor("identity")
    target = jax.random.normal(key, (8, 8))
    est = jnp.zeros_like(target)
    _, _, est = ef_compress_step(comp, {}, est, target, jnp.float32)
    np.testing.assert_allclose(np.asarray(est), np.asarray(target),
                               rtol=1e-6)
