"""Error-feedback algebra + the paper's motivating divergence example.

The EF-necessity experiment (§2 "Error Feedback", Beznosikov et al.
Example 1): naive biased compression of gradients diverges on an average
of quadratics, while the EF21 mechanism converges.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import TopK, get_compressor
from repro.core.error_feedback import apply_payload, ef_compress_step


def test_ef_state_bit_consistency(key):
    """Sender and receiver estimates stay identical (the EF21 invariant)."""
    comp = TopK(0.2)
    target = jax.random.normal(key, (12, 12))
    est_send = jnp.zeros((12, 12))
    est_recv = jnp.zeros((12, 12))
    state = comp.init(key, target.shape, jnp.float32)
    for i in range(5):
        payload, state, est_send = ef_compress_step(comp, state, est_send,
                                                    target, jnp.float32)
        est_recv = apply_payload(comp, payload, est_recv)
        np.testing.assert_array_equal(np.asarray(est_send),
                                      np.asarray(est_recv))


def test_ef_estimate_converges_to_fixed_target(key):
    """Repeated EF rounds on a fixed target: ||G - T|| -> 0 geometrically
    (contraction factor sqrt(1 - alpha))."""
    comp = TopK(0.25)
    target = jax.random.normal(key, (20, 20))
    est = jnp.zeros_like(target)
    state = comp.init(key, target.shape, jnp.float32)
    errs = []
    for i in range(30):
        _, state, est = ef_compress_step(comp, state, est, target,
                                         jnp.float32)
        errs.append(float(jnp.linalg.norm(est - target)))
    assert errs[-1] < 1e-3 * errs[0]


def _quadratic_problem():
    """Average of 3 strongly convex quadratics with conflicting gradients
    (the divergence construction of Beznosikov et al. 2020, Example 1)."""
    a = jnp.array([[-3.0, 2.0, 2.0], [2.0, -3.0, 2.0], [2.0, 2.0, -3.0]])

    # f_j(x) = 0.5 x^T (I + e_j e_j^T) x + <a_j, x>; grads differ strongly
    def grad_j(x, j):
        return x + jnp.eye(3)[j] * x[j] + a[j]

    return grad_j


def test_biased_compression_without_ef_fails(key):
    """Top1-compressed gradient descent (no EF) stalls/diverges on the
    quadratic example while EF21 converges to the optimum."""
    grad_j = _quadratic_problem()
    comp = TopK(0.34)  # top-1 of 3
    lr = 0.1

    def naive(x0, steps=300):
        x = x0
        for _ in range(steps):
            g = jnp.mean(jnp.stack([
                comp.decompress(comp.compress({}, grad_j(x, j))[0],
                                (3,), jnp.float32) for j in range(3)]), 0)
            x = x - lr * g
        return x

    def ef21(x0, steps=300):
        x = x0
        G = [jnp.zeros(3)] * 3
        for _ in range(steps):
            for j in range(3):
                _, _, G[j] = ef_compress_step(comp, {}, G[j], grad_j(x, j),
                                              jnp.float32)
            x = x - lr * jnp.mean(jnp.stack(G), 0)
        return x

    x0 = jnp.array([1.0, 0.7, -0.3])
    # optimum: grad f(x*) = 0 for f = mean f_j
    def full_grad(x):
        return jnp.mean(jnp.stack([grad_j(x, j) for j in range(3)]), 0)

    x_naive = naive(x0)
    x_ef = ef21(x0)
    gn_naive = float(jnp.linalg.norm(full_grad(x_naive)))
    gn_ef = float(jnp.linalg.norm(full_grad(x_ef)))
    assert gn_ef < 1e-3, f"EF21 should converge, got grad norm {gn_ef}"
    assert gn_naive > 10 * gn_ef, \
        f"naive compression should stall: {gn_naive} vs {gn_ef}"


def test_identity_compressor_ef_is_exact(key):
    comp = get_compressor("identity")
    target = jax.random.normal(key, (8, 8))
    est = jnp.zeros_like(target)
    _, _, est = ef_compress_step(comp, {}, est, target, jnp.float32)
    np.testing.assert_allclose(np.asarray(est), np.asarray(target),
                               rtol=1e-6)
