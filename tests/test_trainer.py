"""Integration: distributed trainer, data pipeline, serving, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.schedule import (constant, cosine, theory_radius,
                                 warmup_linear_decay)
from repro.data import SyntheticLM
from repro.models.api import build_model, make_batch
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.serve import Server
from repro.train.trainer import Trainer, TrainerConfig


def test_synthetic_data_deterministic_and_heterogeneous():
    cfg = get_config("granite-3-2b").reduced()
    sh = ShapeSpec("t", "train", 32, 8)
    d = SyntheticLM(cfg, sh, n_workers=4, seed=3)
    b1, b2 = d.batch_at(7), d.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 2, 32)
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["labels"][..., :-1]),
                                  np.asarray(b1["tokens"][..., 1:]))
    # workers see different streams (heterogeneity)
    assert not np.array_equal(np.asarray(b1["tokens"][0]),
                              np.asarray(b1["tokens"][1]))


def test_vlm_audio_batches_have_stub_frontends(key):
    sh = ShapeSpec("t", "train", 16, 4)
    vlm = SyntheticLM(get_config("qwen2-vl-7b").reduced(), sh, 2).batch_at(0)
    assert set(vlm) == {"embeds", "pos", "labels"}
    assert vlm["pos"].shape[-1] == 3
    aud = SyntheticLM(get_config("whisper-small").reduced(), sh,
                      2).batch_at(0)
    assert "frames" in aud and "tokens" in aud


def test_trainer_loss_decreases(key):
    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    sh = ShapeSpec("t", "train", 64, 8)
    data = SyntheticLM(cfg, sh, n_workers=2, seed=0)
    tr = Trainer(model, TrainerConfig(n_workers=2, beta=0.5, w2s="top10",
                                      remat=False, use_pallas=False))
    state = tr.init(key)
    step = jax.jit(tr.make_step())
    sched = warmup_linear_decay(0.01, 5, 40)
    losses = []
    for i in range(40):
        state, aux = step(state, data.batch_at(i), sched(i))
        losses.append(float(aux["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::8]
    assert int(state["step"]) == 40


def test_donated_step_executes_and_matches(key):
    """Donation regression: tied leaves (shared embed/unembed) are the
    same buffer at init, and XLA rejects donating one buffer twice —
    Trainer.init must de-alias repeats when tcfg.donate is set. Run
    real donated steps (the static lint only compiles) and pin them
    value-equal to the undonated arm."""
    cfg = get_config("granite-3-2b").reduced()
    sh = ShapeSpec("t", "train", 64, 8)
    data = SyntheticLM(cfg, sh, n_workers=2, seed=0)
    sched = warmup_linear_decay(0.01, 2, 10)
    results = {}
    for donate in (False, True):
        tr = Trainer(build_model(cfg),
                     TrainerConfig(n_workers=2, beta=0.5, w2s="top10",
                                   remat=False, use_pallas=False,
                                   donate=donate))
        state = tr.init(key)
        step = tr.jit_step(None)
        for i in range(3):
            state, aux = step(state, data.batch_at(i), sched(i))
        results[donate] = (float(aux["loss"]), int(state["step"]))
    assert results[True] == results[False], results


def test_checkpoint_roundtrip(tmp_path, key):
    cfg = get_config("qwen2.5-3b").reduced()
    model = build_model(cfg)
    tr = Trainer(model, TrainerConfig(n_workers=2, w2s="top10",
                                      use_pallas=False))
    state = tr.init(key)
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, state, step=17)
    state2, step = load_checkpoint(path, state)
    assert step == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_server_generate(key):
    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    server = Server(model)
    batch = make_batch(cfg, ShapeSpec("p", "prefill", 8, 2), key)
    toks = server.generate(params, batch, max_new=4)
    assert toks.shape == (2, 4)
    assert toks.dtype == jnp.int32
    # greedy decoding is deterministic
    toks2 = server.generate(params, batch, max_new=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_schedules():
    s = warmup_linear_decay(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < float(s(10))
    c = cosine(1.0, 10, 100)
    assert float(c(100)) < 1e-6 + float(c(55))
    t = theory_radius(2.0, 99)
    assert abs(float(t(0)) - 0.2) < 1e-6
    assert float(constant(0.3)(5)) == pytest.approx(0.3)


def test_state_shapes_match_real_init(key):
    """eval_shape-built abstract state == concrete init (the dry-run
    contract)."""
    cfg = get_config("mixtral-8x7b").reduced()
    model = build_model(cfg)
    tr = Trainer(model, TrainerConfig(n_workers=2, w2s="rank10",
                                      use_pallas=False))
    abstract = tr.state_shapes()
    concrete = tr.init(key)
    ab_l, ab_t = jax.tree.flatten(abstract)
    co_l, co_t = jax.tree.flatten(concrete)
    assert ab_t == co_t
    for a, c in zip(ab_l, co_l):
        assert a.shape == c.shape and a.dtype == c.dtype
