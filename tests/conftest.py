import os
import sys

# src-layout import path (PYTHONPATH=src also works)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)
