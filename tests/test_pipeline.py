"""Staged wire pipeline (DESIGN.md §8): stage assignment over the NS
buckets, the byte-exact repartition of the wire buffer into per-stage
sub-buffers, bit-exact per-stage pack/unpack (hypothesis-swept incl.
odd shapes and stacked leaves), and staged-vs-monolithic step
bit-equality on the jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.layerwise import LayerPlan
from repro.dist.pipeline import (bucket_ns_flops, build_stage_plan,
                                 s2w_issue_order)
from repro.wire.layout import build_staged_layout


def _tree(key):
    """Eager (sign) leaves + three NS buckets of different FLOP weight:
    (32, 48) batch 5, (32, 80) batch 2, (16, 16) batch 1."""
    ks = jax.random.split(key, 7)
    params = {
        "wq": jax.random.normal(ks[0], (48, 32)),
        "wk": jax.random.normal(ks[1], (48, 32)),
        "w_in": jax.random.normal(ks[2], (32, 80)),
        "w_out": jax.random.normal(ks[3], (80, 32)),
        "blocks": jax.random.normal(ks[4], (3, 48, 32)),
        "tiny": jax.random.normal(ks[5], (16, 16)),
        "bias": jax.random.normal(ks[6], (32,)),
    }
    metas = {
        "wq": ParamMeta("spectral", 1.0, 0),
        "wk": ParamMeta("spectral", 1.0, 0),
        "w_in": ParamMeta("spectral", 1.5, 0),
        "w_out": ParamMeta("spectral", 1.0, 0),
        "blocks": ParamMeta("spectral", 2.0, 1),
        "tiny": ParamMeta("spectral", 1.0, 0),
        "bias": ParamMeta("sign", 1.0, 0, compressible=False),
    }
    return params, metas


# ------------------------------------------------------- stage assignment

def test_stage_plan_partitions_leaves(key):
    params, metas = _tree(key)
    plan = LayerPlan.build(params, metas, w2s="top10")
    sp = plan.stage_plan()
    assert sp is plan.stage_plan()                       # memoised
    # every leaf in exactly one stage
    all_ids = sorted(i for s in sp.stages for i in s.leaf_ids)
    assert all_ids == list(range(len(plan.leaves)))
    # stage 0 is the eager chunk: exactly the non-bucketed leaves
    buckets = plan.ns_buckets()
    bucketed = {i for b in buckets for i in b.leaf_ids}
    assert set(sp.stages[0].leaf_ids) == \
        set(range(len(plan.leaves))) - bucketed
    assert sp.stages[0].bucket_ids == ()
    assert sp.eager_leaf_ids == sp.stages[0].leaf_ids
    # one stage per bucket, descending by NS FLOPs
    assert sp.n_stages == 1 + len(buckets)
    flops = [s.ns_flops for s in sp.stages[1:]]
    assert flops == sorted(flops, reverse=True)
    for s in sp.stages[1:]:
        (bi,) = s.bucket_ids
        assert sorted(buckets[bi].leaf_ids) == list(s.leaf_ids)
        assert s.ns_flops == bucket_ns_flops(buckets[bi])


def test_stage_plan_cap_merges_smallest_tail(key):
    params, metas = _tree(key)
    plan = LayerPlan.build(params, metas, w2s="top10")
    auto = plan.stage_plan()
    assert auto.n_stages == 4          # eager + 3 buckets
    capped = plan.stage_plan(wire_stages=3)
    assert capped.n_stages == 3
    # head stages unchanged, tail merged (smallest-FLOP buckets last)
    assert capped.stages[:2] == auto.stages[:2]
    merged = capped.stages[2]
    assert set(merged.leaf_ids) == set(auto.stages[2].leaf_ids) \
        | set(auto.stages[3].leaf_ids)
    assert merged.ns_flops == auto.stages[2].ns_flops \
        + auto.stages[3].ns_flops
    # cap below the floor: everything in one stage; cap above: auto
    assert plan.stage_plan(wire_stages=1).n_stages == 1
    assert plan.stage_plan(wire_stages=99).stages == auto.stages
    with pytest.raises(ValueError):
        build_stage_plan(plan, plan.ns_buckets(), wire_stages=0)


def test_s2w_issue_order_descending_receive_work(key):
    """The s2w broadcast issue order (§9): a deterministic permutation of
    the stage indices, descending by per-stage receive work (leaf element
    counts — the decompress+apply chain each broadcast must hide), NOT by
    the NS FLOPs that ordered the w2s stages."""
    params, metas = _tree(key)
    plan = LayerPlan.build(params, metas, w2s="top10", s2w="natural")
    sp = plan.stage_plan()
    order = s2w_issue_order(plan, sp)
    assert sorted(order) == list(range(sp.n_stages))
    assert order == s2w_issue_order(plan, sp)            # deterministic

    def work(k):
        return sum(np.prod(plan.leaves[i].shape) for i in sp.stages[k].leaf_ids)

    works = [work(k) for k in order]
    assert works == sorted(works, reverse=True)
    # ties break on stage index (stable ascending within equal work)
    for a, b in zip(order, order[1:]):
        if work(a) == work(b):
            assert a < b
    # the ordering is a schedule, not a repartition: every leaf still
    # appears exactly once across the ordered stages
    all_ids = sorted(i for k in order for i in sp.stages[k].leaf_ids)
    assert all_ids == list(range(len(plan.leaves)))


def test_stage_plan_no_buckets_is_single_stage(key):
    params = {"v": jax.random.normal(key, (8,))}
    metas = {"v": ParamMeta("sign", 1.0, 0)}
    plan = LayerPlan.build(params, metas, w2s="top10")
    sp = plan.stage_plan()
    assert sp.n_stages == 1 and sp.stages[0].leaf_ids == (0,)


# ------------------------------------------- staged layout: byte repartition

def test_staged_layout_byte_exact_repartition(key):
    params, metas = _tree(key)
    plan = LayerPlan.build(params, metas, w2s="top10+natural")
    sp = plan.stage_plan()
    layout = plan.wire_layout(jnp.bfloat16)
    staged = plan.staged_wire_layout(jnp.bfloat16, sp)
    assert staged is plan.staged_wire_layout(jnp.bfloat16, sp)  # memoised
    assert staged.base is layout
    assert staged.n_stages == sp.n_stages
    # stage bytes sum byte-for-byte to the monolithic buffer (the
    # relaxed K-gather wire invariant)
    assert sum(staged.stage_nbytes(k) for k in range(staged.n_stages)) \
        == layout.total_nbytes
    # per stage: offsets contiguous, per-leaf byte layout preserved
    for k, ids in enumerate(staged.stage_leaf_ids):
        pos = 0
        for spec, i in zip(staged.stages[k].specs, ids):
            base = layout.specs[i]
            assert spec.offset == pos
            pos += spec.region_nbytes
            assert (spec.slice_nbytes, spec.stack_shape, spec.codecs) == \
                (base.slice_nbytes, base.stack_shape, base.codecs)
        assert pos == staged.stages[k].total_nbytes
    # a non-partition is rejected
    with pytest.raises(ValueError):
        build_staged_layout(layout, ((0, 1), (1, 2)))


def _payloads_for(plan, key, n_workers=2, direction="w2s"):
    """Real per-leaf payload trees with [n_workers, *stack] leading dims,
    exactly as phase 3 (w2s) / phase 1 (s2w, lead dim 1) produces them."""
    out = []
    for j, lp in enumerate(plan.leaves):
        comp = getattr(lp, direction)
        wire = jnp.dtype(jnp.bfloat16)
        in_dtype = (jnp.float32
                    if getattr(comp, "lossless_wire", False) else wire)

        def one(k, c=comp, s=lp.slice_shape, d=in_dtype):
            x = jax.random.normal(k, s, jnp.float32).astype(d)
            payload, _ = c.compress(c.init(k, s, jnp.dtype(jnp.bfloat16)), x)
            return payload

        keys = jax.random.split(jax.random.fold_in(key, j),
                                n_workers * lp.n_stack).reshape(
                                    (n_workers,) + lp.stack_shape)
        fn = one
        for _ in range(lp.meta.stack_dims + 1):
            fn = jax.vmap(fn)
        out.append(fn(keys))
    return out


def test_staged_pack_unpack_roundtrip_bitexact(key):
    params, metas = _tree(key)
    plan = LayerPlan.build(params, metas, w2s="top10+natural")
    staged = plan.staged_wire_layout(jnp.bfloat16, plan.stage_plan())
    payloads = _payloads_for(plan, key)
    for k, ids in enumerate(staged.stage_leaf_ids):
        buf = staged.pack_stage(k, payloads)
        assert buf.dtype == jnp.uint8
        assert buf.shape == (2, staged.stage_nbytes(k))
        got = staged.unpack_stage(k, buf)
        for i, g in zip(ids, got):
            la, ta = jax.tree.flatten(g)
            lb, tb = jax.tree.flatten(payloads[i])
            assert ta == tb
            for x, y in zip(la, lb):
                assert x.dtype == y.dtype
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(name=st.sampled_from(["top10+natural", "top10", "natural",
                             "identity", "identity+natural"]),
       direction=st.sampled_from(["w2s", "s2w"]),
       L=st.integers(1, 3), m=st.integers(3, 17), n=st.integers(3, 17),
       stages=st.sampled_from(["auto", 1, 2]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_staged_roundtrip_property(name, direction, L, m, n, stages, seed):
    """Hypothesis: per-stage pack -> unpack is the identity bit-for-bit
    for arbitrary odd shapes, stacked leaves and stage caps, in BOTH wire
    directions (the s2w leg reuses the same leaf partition, §9), and the
    stage bytes always repartition the base buffer exactly."""
    key = jax.random.key(seed)
    params = {"w": jax.ShapeDtypeStruct((m, n), jnp.float32),
              "s": jax.ShapeDtypeStruct((L, n, m), jnp.float32),
              "v": jax.ShapeDtypeStruct((m,), jnp.float32)}
    metas = {"w": ParamMeta("spectral", 1.0, 0),
             "s": ParamMeta("spectral", 1.0, 1),
             "v": ParamMeta("sign", 1.0, 0, compressible=False)}
    plan = LayerPlan.build(params, metas, w2s=name, s2w=name)
    staged = plan.staged_wire_layout(
        jnp.bfloat16, plan.stage_plan(wire_stages=stages),
        direction=direction)
    assert staged.direction == direction
    assert sum(staged.stage_nbytes(k) for k in range(staged.n_stages)) \
        == plan.wire_layout(jnp.bfloat16, direction=direction).total_nbytes
    payloads = _payloads_for(plan, key, n_workers=1, direction=direction)
    for k, ids in enumerate(staged.stage_leaf_ids):
        got = staged.unpack_stage(k, staged.pack_stage(k, payloads))
        for i, g in zip(ids, got):
            la, _ = jax.tree.flatten(g)
            lb, _ = jax.tree.flatten(payloads[i])
            for x, y in zip(la, lb):
                assert x.dtype == y.dtype
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------- staged step equivalence

def _quadratic_grad(params, batch):
    loss = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - batch))
               for p in jax.tree.leaves(params))
    grads = jax.tree.map(
        lambda p: 2.0 * (p.astype(jnp.float32) - batch), params)
    return loss, grads


def _run_steps(params, metas, key, wire_stages, n=3, **cfg_kw):
    opt = EF21Muon(EF21MuonConfig(n_workers=2, beta=0.5,
                                  w2s="top10+natural", s2w="natural",
                                  use_pallas=False,
                                  wire_stages=wire_stages, **cfg_kw))
    state = opt.init(key, params, metas)
    fn = opt.make_step(metas, reshard_payloads=lambda t: t)
    step = jax.jit(lambda s, b, t, f=fn: f(s, _quadratic_grad, b, t))
    for _ in range(n):
        state, aux = step(state, jnp.ones((2, 1)) * 0.1, 0.01)
    assert np.isfinite(float(aux["loss"]))
    return state


def test_staged_step_bit_equal_monolithic(key):
    """The §8 acceptance invariant on the jnp path: the staged step
    (auto and a capped stage count) is value-bit-equal to the
    wire_stages=1 monolithic step — staging is a pure repartition."""
    params, metas = _tree(key)
    mono = _run_steps(params, metas, key, wire_stages=1)
    for ws in ("auto", 2):
        staged = _run_steps(params, metas, key, wire_stages=ws)
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                            staged, mono)
        assert all(jax.tree.leaves(same)), (ws, same)


def test_staged_collapses_without_bucketing(key):
    """ns_bucketing=False leaves no buckets to stage against: the step
    must fall back to the monolithic single-buffer path (bit-equal)."""
    params, metas = _tree(key)
    a = _run_steps(params, metas, key, wire_stages="auto",
                   ns_bucketing=False)
    b = _run_steps(params, metas, key, wire_stages=1, ns_bucketing=False)
    same = jax.tree.map(lambda x, y: bool(jnp.all(x == y)), a, b)
    assert all(jax.tree.leaves(same))


def test_s2w_wire_leg_bit_equal_off_arm(key):
    """The §9 A/B switch: routing the EF21-P model update through the
    staged s2w wire buffers (wire_pack_s2w auto-engages here — the hook
    is set and wire_pack is on) is value-bit-equal to the unpacked
    phase-1 path, for both the staged and the monolithic schedule."""
    params, metas = _tree(key)
    for ws in ("auto", 1):
        on = _run_steps(params, metas, key, wire_stages=ws)
        off = _run_steps(params, metas, key, wire_stages=ws,
                         wire_pack_s2w=False)
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), on, off)
        assert all(jax.tree.leaves(same)), (ws, same)
