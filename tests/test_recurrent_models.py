"""xLSTM / Griffin recurrence correctness: the chunkwise-parallel and
associative-scan training paths must equal the exact sequential decode
cells (these are the model-level oracles for the SSM/hybrid families)."""
import jax
import jax.numpy as jnp
import numpy as np
# property tests skip individually when hypothesis is absent; the
# plain oracle tests in this file still run (see _hypothesis_compat)
from _hypothesis_compat import given, settings, st

from repro.models.xlstm import mlstm_chunkwise, mlstm_step
from repro.models.griffin import rglru, rglru_step, _causal_conv


@given(s=st.integers(1, 50), chunk=st.sampled_from([4, 16, 64]),
       seed=st.integers(0, 2 ** 12))
@settings(max_examples=12, deadline=None)
def test_mlstm_chunkwise_equals_sequential(s, chunk, seed):
    B, H, hd = 2, 2, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, s, H, hd))
    k = jax.random.normal(ks[1], (B, s, H, hd))
    v = jax.random.normal(ks[2], (B, s, H, hd))
    li = jax.random.normal(ks[3], (B, s, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, s, H)) + 2.0)
    hc, st_c = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    st_ = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
           jnp.full((B, H), -1e30))
    outs = []
    for t in range(s):
        h1, st_ = mlstm_step(q[:, t], k[:, t], v[:, t], li[:, t], lf[:, t],
                             st_)
        outs.append(h1)
    hs = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs), rtol=2e-3,
                               atol=2e-3)
    # states agree in the destabilised scale
    c_chunk = st_c[0] * jnp.exp(st_c[2])[..., None, None]
    c_seq = st_[0] * jnp.exp(st_[2])[..., None, None]
    np.testing.assert_allclose(np.asarray(c_chunk), np.asarray(c_seq),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_stability_extreme_gates(key):
    """Log-space stabilisation: no NaN/inf for extreme gate values."""
    B, S, H, hd = 1, 32, 2, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    li = jnp.full((B, S, H), 30.0)        # huge input gate
    lf = jnp.full((B, S, H), -30.0)       # tiny forget gate
    h, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=8)
    assert not bool(jnp.any(jnp.isnan(h)))
    li = jnp.full((B, S, H), -40.0)
    lf = jnp.full((B, S, H), -0.001)
    h, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=8)
    assert not bool(jnp.any(jnp.isnan(h)))


@given(s=st.integers(1, 40), seed=st.integers(0, 2 ** 12))
@settings(max_examples=10, deadline=None)
def test_rglru_scan_equals_step(s, seed):
    """Associative-scan RG-LRU == exact per-step recurrence."""
    B, D = 2, 8
    ks = jax.random.split(jax.random.key(seed), 6)
    p = {"w_r": jax.random.normal(ks[0], (D, D)) * 0.3,
         "b_r": jax.random.normal(ks[1], (D,)) * 0.1,
         "w_i": jax.random.normal(ks[2], (D, D)) * 0.3,
         "b_i": jax.random.normal(ks[3], (D,)) * 0.1,
         "lam": jnp.full((D,), 0.65)}
    x = jax.random.normal(ks[4], (B, s, D))
    y_scan, h_last = rglru(x, p, None)
    h = jnp.zeros((B, D))
    outs = []
    for t in range(s):
        y, h = rglru_step(x[:, t], p, h)
        outs.append(y)
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-4, atol=1e-5)


def test_rglru_state_carry(key):
    """rglru(x, h0) == continuing the recurrence from h0."""
    B, S, D = 1, 10, 4
    ks = jax.random.split(key, 6)
    p = {"w_r": jax.random.normal(ks[0], (D, D)) * 0.3,
         "b_r": jnp.zeros((D,)), "w_i": jax.random.normal(ks[1], (D, D)),
         "b_i": jnp.zeros((D,)), "lam": jnp.full((D,), 0.65)}
    x = jax.random.normal(ks[2], (B, S, D))
    y_all, _ = rglru(x, p, None)
    y_a, h_mid = rglru(x[:, :4], p, None)
    y_b, _ = rglru(x[:, 4:], p, h_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-5)


def test_causal_conv_tail_consistency(key):
    """Full-sequence conv == step-by-step conv with tail state."""
    B, S, D, W = 2, 9, 4, 4
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (B, S, D))
    w = jax.random.normal(ks[1], (W, D)) * 0.3
    b = jax.random.normal(ks[2], (D,)) * 0.1
    y_full, _ = _causal_conv(x, w, b, None)
    tail = jnp.zeros((B, W - 1, D))
    outs = []
    for t in range(S):
        y, tail = _causal_conv(x[:, t:t + 1], w, b, tail)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-6)


def test_xlstm_prefill_decode_vs_full(key):
    """End-to-end xLSTM: prefill+decode logits == full forward."""
    from repro.configs import get_config
    from repro.models.api import build_model
    from repro.models.common import logits_last
    cfg = get_config("xlstm-1.3b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab)
    cache = model.init_cache(2, 16)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    lg_dec, _ = model.decode_step(
        params, {"token": toks[:, 8:9], "t": jnp.asarray(8, jnp.int32)},
        cache)
    x = params["embed"][toks]
    h, _ = model._run(params, x, None, "full", False)
    lg_full = logits_last(h[:, -1], params["unembed"])
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=0.06, atol=0.06)


def test_griffin_prefill_decode_vs_full(key):
    from repro.configs import get_config
    from repro.models.api import build_model
    from repro.models.common import logits_last
    cfg = get_config("recurrentgemma-2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab)
    cache = model.init_cache(2, 16)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    lg_dec, _ = model.decode_step(
        params, {"token": toks[:, 8:9], "t": jnp.asarray(8, jnp.int32)},
        cache)
    x = params["embed"][toks]
    pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
    h, _ = model._run(params, x, pos, None, None, "full", False)
    lg_full = logits_last(h[:, -1], params["embed"].T)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=0.06, atol=0.06)
