"""repro.dist units beyond the seed contract: serve-cache batch-dim
disambiguation, LayerPlan wire accounting, and the Server mesh path."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.sharding import serve_pspecs
# bare module import: tests/ has no __init__.py, so pytest puts the dir
# itself on sys.path — works under both `pytest` and `python -m pytest`
from test_sharding import FakeMesh

MESH = FakeMesh(data=16, model=16)


class S:
    def __init__(self, shape):
        self.shape = shape


def test_serve_pspecs_batch_eq_layers_prefers_batch_dim():
    """[n_layers, batch, ...] cache with n_layers == batch: the batch dim
    (index 1), not the layer stack, must land on 'data'."""
    cache = {"k": S((48, 48, 32768, 8, 64))}
    spec = serve_pspecs(cache, 48, MESH)["k"]
    assert spec[0] is None and spec[1] == "data"
    assert spec[2] == "model"          # sequence dim still sharded
    # 2-D recurrent state [batch, d]: batch stays at dim 0
    spec2 = serve_pspecs({"h": S((48, 48))}, 48, MESH)["h"]
    assert spec2[0] == "data"
    # a later same-size dim must NOT displace a genuine batch at dim 0
    spec3 = serve_pspecs({"s": S((48, 64, 48))}, 48, MESH)["s"]
    assert spec3[0] == "data" and spec3[2] != "data"


def test_serve_pspecs_cache_alt_finds_batch_exactly():
    """With cache_alt (the spec at another batch size) the batch dim is
    found by shape diff — exact for recurrent layouts where batch sits
    deeper than dim 1 and leading dims coincide with the batch size."""
    # xlstm-like leaf [n_blocks=16, heads=16, batch=16, hd, hd]:
    # every leading dim equals the batch size
    cache = {"C": S((16, 16, 16, 128, 128))}
    alt = {"C": S((16, 16, 17, 128, 128))}
    spec = serve_pspecs(cache, 16, MESH, cache_alt=alt)["C"]
    assert spec[2] == "data" and spec[0] is None and spec[1] is None
    # and against a real model: xlstm cache has batch at dim 2
    import jax
    from repro.configs import get_config
    from repro.models.api import build_model

    model = build_model(get_config("xlstm-1.3b").reduced())
    c = model.cache_spec(16, 32)
    a = model.cache_spec(17, 32)
    specs = serve_pspecs(c, 16, MESH, cache_alt=a)
    big = jax.tree.leaves(specs)[0]
    assert big[2] == "data"


def test_server_mesh_path_matches_single_host(key):
    """The mesh branch of Server (metas capture, shardings, _place) on a
    1-device mesh: placement resolves and greedy decode is bit-identical
    to the single-host path."""
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.models.api import build_model, make_batch
    from repro.train.serve import Server

    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    batch = make_batch(cfg, ShapeSpec("p", "prefill", 8, 2), key)
    srv = Server(model, mesh=mesh)
    cache = model.init_cache(2, 12)
    p_sh, b_sh, c_sh = srv.shardings(params, batch, cache)
    assert all(s.mesh is mesh for s in jax.tree.leaves(p_sh))
    toks = srv.generate(params, batch, max_new=4)
    toks0 = Server(model).generate(params, batch, max_new=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks0))


def test_layer_plan_is_cached_and_accounts_bytes():
    opt = EF21Muon(EF21MuonConfig(n_workers=2, w2s="top10"))
    params = {"w": jnp.zeros((8, 16, 32)), "v": jnp.zeros((64,))}
    metas = {"w": ParamMeta("spectral", 1.0, 1),
             "v": ParamMeta("sign", 1.0, 0, compressible=False)}
    plan = opt.plan(params, metas)
    assert opt.plan(params, metas) is plan          # cached
    wire = plan.w2s_bytes_per_worker(jnp.bfloat16)
    assert wire == opt.w2s_bytes_per_worker(params, metas)
    # incompressible leaf ships dense (identity), compressible leaf doesn't
    dense_v = 64 * 2
    assert wire > dense_v
    assert wire < plan.dense_bytes(jnp.bfloat16)
