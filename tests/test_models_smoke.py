"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of the same family and runs one forward /
train-grad step and a prefill+decode step on CPU, asserting output shapes
and the absence of NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeSpec
from repro.models.api import build_model, input_specs, make_batch

ASSIGNED = [a for a in ARCHS if a != "nanogpt-124m"]


def _tiny(cfg):
    """Shrink further for CPU speed (keeps family structure)."""
    return cfg


@pytest.mark.parametrize("arch", ASSIGNED + ["nanogpt-124m"])
def test_train_step_smoke(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, metas = model.init(key)
    leaves = jax.tree.leaves(params)
    assert leaves and all(not bool(jnp.any(jnp.isnan(
        p.astype(jnp.float32)))) for p in leaves)
    # metas tree mirrors params tree
    jax.tree.map(lambda p, m: None, params, metas)

    batch = make_batch(cfg, ShapeSpec("t", "train", 24, 2), key, 1)
    b0 = jax.tree.map(lambda x: x[0], batch)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, b0, remat=False))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and not jnp.isnan(gnorm), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_smoke(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    B, S = 2, 8
    cache = model.init_cache(B, 16)
    pre = make_batch(cfg, ShapeSpec("p", "prefill", S, B), key)
    logits, cache = model.prefill(params, pre, cache)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    for t in range(S, S + 3):
        dec = {"token": jnp.ones((B, 1), jnp.int32),
               "t": jnp.asarray(t, jnp.int32)}
        logits, cache = model.decode_step(params, dec, cache)
        assert logits.shape == (B, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(logits))), arch


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2.5-3b",
                                  "mixtral-8x7b", "deepseek-v3-671b"])
def test_decode_matches_full_forward(arch, key):
    """prefill(x[:8]) + decode(x[8]) logits == full forward logits at
    position 8 (exactness of the serving path).

    MoE archs use a no-drop capacity factor: capacity-based dispatch
    legitimately drops different tokens for different batch sizes, so
    exactness only holds when nothing is dropped."""
    import numpy as np
    cfg = get_config(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params, _ = model.init(key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab)
    cache = model.init_cache(2, 16)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    lg_dec, _ = model.decode_step(
        params, {"token": toks[:, 8:9], "t": jnp.asarray(8, jnp.int32)},
        cache)
    # full forward over 9 tokens
    x, pos = model._embed_in(params, {"tokens": toks})
    h, _, _ = model._run(params, x, pos, None, None, "full", False)
    from repro.models.common import logits_last
    lg_full = logits_last(h[:, -1], model._unembed(params))
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=0.05, atol=0.05)


def test_sliding_window_ring_cache(key):
    """Windowed arch (starcoder2): decode against a ring cache matches the
    full forward with the same window."""
    import numpy as np
    cfg = dataclasses.replace(get_config("starcoder2-15b").reduced(),
                              window=6)
    model = build_model(cfg)
    params, _ = model.init(key)
    S = 12  # prompt longer than the window
    toks = jax.random.randint(key, (2, S + 1), 0, cfg.vocab)
    cache = model.init_cache(2, 32)
    assert cache["dense_blocks"]["k"].shape[2] == 6  # ring capacity
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, cache)
    lg_dec, _ = model.decode_step(
        params, {"token": toks[:, S:S + 1], "t": jnp.asarray(S, jnp.int32)},
        cache)
    x, pos = model._embed_in(params, {"tokens": toks})
    h, _, _ = model._run(params, x, pos, None, None, "full", False)
    from repro.models.common import logits_last
    lg_full = logits_last(h[:, -1], model._unembed(params))
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full),
                               rtol=0.05, atol=0.05)


def test_input_specs_cover_all_shapes():
    """input_specs produces a spec for every (arch x shape) pair and the
    decode cache spec exists (used verbatim by the dry-run)."""
    from repro.configs import SHAPES
    for arch in ASSIGNED:
        cfg = get_config(arch)
        model = build_model(cfg)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape, n_workers=16
                                if shape.kind == "train" else 1)
            assert specs
            if shape.kind == "decode":
                cs = model.cache_spec(shape.batch, shape.seq)
                assert jax.tree.leaves(cs)


def test_mtp_loss_included(key):
    """DeepSeek MTP head contributes to the loss."""
    cfg = get_config("deepseek-v3-671b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    assert "mtp" in params
    batch = make_batch(cfg, ShapeSpec("t", "train", 16, 2), key, 1)
    b0 = jax.tree.map(lambda x: x[0], batch)
    g = jax.grad(lambda p: model.loss(p, b0, remat=False))(params)
    gn = float(jnp.sum(jnp.abs(g["mtp"]["proj"].astype(jnp.float32))))
    assert gn > 0  # MTP params receive gradient


def test_moe_router_balanced_dispatch(key):
    """MoE: all experts receive nonzero routing mass on random input."""
    from repro.models.transformer import _moe_ffn
    cfg = get_config("mixtral-8x7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(key)
    p = jax.tree.map(lambda a: a[0], params["moe_blocks"]["moe"])
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out, aux = _moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert float(aux) > 0
    assert not bool(jnp.any(jnp.isnan(out)))
