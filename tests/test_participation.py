"""Elastic participation + fault injection (DESIGN.md §11).

Schedule algebra (dist/participation.py), the FaultPlan grammar and
injectors (train/faults.py), and the optimizer-level degradation
semantics: guard demotion, skip-step fallback, chaos-run finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.participation import (Explicit, mask_bcast,
                                      participation_mask,
                                      payload_finite_mask, validate_spec)
from repro.train.faults import (DropFault, FaultPlan, GradFault, WireFault,
                                parse_faults)


# ----------------------------------------------------------- schedules

def test_full_schedule_is_all_ones():
    for step in (0, 7):
        m = participation_mask("full", 4, step)
        assert m.shape == (4,) and bool(jnp.all(m))


def test_round_robin_rotates_and_covers():
    n, k = 5, 2
    seen = np.zeros(n, int)
    for step in range(n):
        m = np.asarray(participation_mask(f"round_robin({k})", n, step))
        assert m.sum() == k
        seen += m
    assert (seen == k).all()   # every worker participates k/n of steps


def test_round_robin_full_window_is_all_ones():
    m = participation_mask("round_robin(4)", 4, 3)
    assert bool(jnp.all(m))


def test_bernoulli_deterministic_and_step_varying():
    a = np.asarray(participation_mask("bernoulli(0.5)", 8, 3, seed=1))
    b = np.asarray(participation_mask("bernoulli(0.5)", 8, 3, seed=1))
    assert (a == b).all()    # same (spec, seed, step) => same mask
    masks = [np.asarray(participation_mask("bernoulli(0.5)", 8, s, seed=1))
             for s in range(16)]
    assert any(not (m == masks[0]).all() for m in masks[1:])


def test_explicit_table_cycles():
    spec = Explicit(((1, 0), (0, 1)))
    m0 = np.asarray(participation_mask(spec, 2, 0))
    m2 = np.asarray(participation_mask(spec, 2, 2))
    assert (m0 == [True, False]).all() and (m0 == m2).all()
    assert (np.asarray(participation_mask(spec, 2, 1))
            == [False, True]).all()


def test_participation_mask_traced_step():
    f = jax.jit(lambda s: participation_mask("round_robin(1)", 3, s))
    assert np.asarray(f(2)).sum() == 1


@pytest.mark.parametrize("bad", [
    "bernoulli(0)", "bernoulli(1.5)", "round_robin(0)", "round_robin(9)",
    "nonsense", 42])
def test_validate_spec_rejects(bad):
    with pytest.raises(ValueError):
        validate_spec(bad, 4)


def test_validate_spec_explicit_width_mismatch():
    with pytest.raises(ValueError):
        validate_spec(Explicit(((1, 1),)), 4)
    with pytest.raises(ValueError):
        Explicit(())
    with pytest.raises(ValueError):
        Explicit(((1, 0), (1,)))


def test_payload_finite_mask_flags_only_bad_worker():
    pl = [{"values": jnp.ones((3, 4)).at[1, 2].set(jnp.nan),
           "indices": jnp.zeros((3, 4), jnp.int32)}]
    m = np.asarray(payload_finite_mask(pl, 3))
    assert (m == [True, False, True]).all()
    # integer leaves are never checked (can't encode NaN)
    pl_int = [{"codes": jnp.full((3, 4), 255, jnp.int32)}]
    assert np.asarray(payload_finite_mask(pl_int, 3)).all()


def test_mask_bcast_shape():
    m = jnp.array([True, False])
    assert mask_bcast(m, 3).shape == (2, 1, 1)


# ---------------------------------------------------------- fault plan

def test_parse_faults_grammar():
    plan = parse_faults(
        "drop:w=1:steps=5-10,nan:w=0:steps=7,inf:w=2:steps=3-6,"
        "flip:steps=4:bits=16", n_workers=4, seed=3)
    assert plan.drops == (DropFault(1, 5, 10),)
    assert plan.grad_faults == (GradFault(0, 7, 8, "nan"),
                                GradFault(2, 3, 6, "inf"))
    assert plan.wire_faults == (WireFault(4, 5, n_bits=16),)


@pytest.mark.parametrize("bad", [
    "drop:w=9:steps=1", "drop:w=1", "nan:w=0:steps=5-5", "bogus:steps=1"])
def test_parse_faults_rejects(bad):
    with pytest.raises(ValueError):
        parse_faults(bad, n_workers=4)


def test_drop_mask_window():
    plan = FaultPlan(n_workers=3, drops=(DropFault(1, 2, 4),))
    assert np.asarray(plan.drop_mask(1)).all()
    assert (np.asarray(plan.drop_mask(2)) == [True, False, True]).all()
    assert np.asarray(plan.drop_mask(4)).all()


def test_inject_grads_poisons_one_worker_row():
    plan = FaultPlan(n_workers=2, seed=0,
                     grad_faults=(GradFault(1, 0, 2, "nan", leaf_id=0),))
    g = {"a": jnp.ones((2, 3))}
    out = plan.inject_grads(g, 0)
    assert bool(jnp.all(jnp.isnan(out["a"][1])))
    assert bool(jnp.all(out["a"][0] == 1.0))
    # outside the window: untouched
    assert bool(jnp.all(out["a"][0] == plan.inject_grads(g, 5)["a"][0]))
    assert not bool(jnp.any(jnp.isnan(plan.inject_grads(g, 5)["a"])))


def test_inject_wire_flips_bytes_deterministically():
    plan = FaultPlan(n_workers=2, seed=1,
                     wire_faults=(WireFault(3, 4, n_bits=4),))
    buf = jnp.zeros((2, 64), jnp.uint8)
    a = np.asarray(plan.inject_wire(buf, 3))
    b = np.asarray(plan.inject_wire(buf, 3))
    assert (a == b).all()
    assert (a != 0).sum() == 2 * 4        # 4 positions, both worker rows
    assert (np.asarray(plan.inject_wire(buf, 2)) == 0).all()  # inactive
    # non-u8 / s2w buffers pass through untouched
    fbuf = jnp.ones((2, 8), jnp.float32)
    assert plan.inject_wire(fbuf, 3) is fbuf
    assert plan.inject_wire(buf, 3, 0, "s2w") is buf


# ------------------------------------------- optimizer-level semantics

def _hetero(key, n_w=4, dim=16):
    Ts = jax.random.normal(key, (n_w, dim, dim))

    def gal(p, wb):
        t = Ts[jnp.int32(wb[0])]
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    return (jnp.zeros((dim, dim)), ParamMeta("spectral", 1.0, 0), gal,
            jnp.arange(float(n_w)).reshape(n_w, 1), Ts)


def _assert_state_finite(state):
    for lf in jax.tree.leaves(state):
        if jnp.issubdtype(lf.dtype, jnp.inexact):
            assert bool(jnp.all(jnp.isfinite(lf)))


def test_guard_demotes_nan_worker_and_stays_finite(key):
    params, metas, gal, batch, _ = _hetero(key)
    plan = FaultPlan(n_workers=4,
                     grad_faults=(GradFault(0, 2, 40, "nan"),))
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False, nonfinite_guard=True))
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas, faults=plan)(
        s, gal, b, 0.05))
    for i in range(10):
        g_poisoned = np.asarray(state["g_w"][0])
        state, aux = step(state, batch)
        assert np.isfinite(float(aux["loss"]))
        if 2 <= i < 40:
            # demoted: the poisoned worker's EF21 state froze
            assert int(aux["n_participants"]) == 3
            assert np.array_equal(np.asarray(state["g_w"][0]), g_poisoned)
    _assert_state_finite(state)


def test_all_poisoned_skips_step(key):
    params, metas, gal, batch, _ = _hetero(key)
    plan = FaultPlan(n_workers=4, grad_faults=tuple(
        GradFault(w, 2, 4, "nan") for w in range(4)))
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False, nonfinite_guard=True))
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas, faults=plan)(
        s, gal, b, 0.05))
    for i in range(6):
        x_prev = np.asarray(state["x"])
        g_prev = np.asarray(state["g_server"])
        state, aux = step(state, batch)
        if i in (2, 3):   # every worker poisoned -> global skip
            assert bool(aux["skipped"])
            assert int(aux["n_participants"]) == 0
            assert np.array_equal(np.asarray(state["x"]), x_prev)
            assert np.array_equal(np.asarray(state["g_server"]), g_prev)
        else:
            assert not bool(aux["skipped"])
    _assert_state_finite(state)


def test_chaos_50_steps_finite_and_converging(key):
    """The ISSUE acceptance run: dropout + NaN/Inf grads + wire flips on
    a declared schedule, 50 jitted steps, everything stays finite and the
    iterate still heads toward the mean-target optimum."""
    params, metas, gal, batch, Ts = _hetero(key)
    plan = parse_faults(
        "drop:w=1:steps=5-15,nan:w=0:steps=3-40,inf:w=3:steps=20-30,"
        "flip:steps=10-12:bits=4", n_workers=4, seed=7)
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False,
                                  participation="bernoulli(0.75)"))
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas, faults=plan)(
        s, gal, b, 0.05))
    for _ in range(50):
        state, aux = step(state, batch)
        assert np.isfinite(float(aux["loss"]))
    _assert_state_finite(state)
    opt_pt = jnp.mean(Ts, axis=0)
    err = float(jnp.linalg.norm(state["x"] - opt_pt)
                / jnp.linalg.norm(opt_pt))
    assert err < 0.6, f"chaos run diverged: rel err {err}"


def test_wire_flip_absorbed_on_packed_path(key):
    """Bit-flips on the packed w2s buffer: flips that decode to NaN are
    demoted by the guard, finite garbage is absorbed by EF21 — either
    way the run stays finite (wire_pack=True exercises inject_wire on
    the real staged/monolithic buffer)."""
    params, metas, gal, batch, _ = _hetero(key)
    plan = FaultPlan(n_workers=4, seed=11,
                     wire_faults=(WireFault(2, 8, n_bits=16),))
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False, nonfinite_guard=True))
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas, faults=plan)(
        s, gal, b, 0.05))
    for _ in range(12):
        state, aux = step(state, batch)
        assert np.isfinite(float(aux["loss"]))
    _assert_state_finite(state)


def test_elastic_metrics_surface(key):
    params, metas, gal, batch, _ = _hetero(key)
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5, w2s="top10",
                                  use_pallas=False,
                                  participation="round_robin(3)",
                                  metrics=True))
    state = opt.init(key, params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas)(s, gal, b, 0.05))
    state, aux = step(state, batch)
    vals = aux["metrics"].host_floats()
    assert vals["part/n_participants"] == 3.0
    assert vals["part/demoted"] == 0.0
    assert vals["part/skipped_step"] == 0.0
    assert int(aux["n_participants"]) == 3


def test_trainer_threads_participation_and_faults(key):
    """TrainerConfig -> EF21MuonConfig plumbing: 'auto' guard resolves on
    when faults/elastic schedules are present, off on the plain arm."""
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.data import SyntheticLM
    from repro.models.api import build_model
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("nanogpt-124m").reduced()
    model = build_model(cfg)
    plan = parse_faults("nan:w=0:steps=2-4", 2)
    tr = Trainer(model, TrainerConfig(
        n_workers=2, beta=0.5, w2s="top10", remat=False, use_pallas=False,
        participation="bernoulli(0.5)", faults=plan))
    assert tr.opt.cfg.nonfinite_guard
    assert tr.opt.cfg.participation == "bernoulli(0.5)"
    plain = Trainer(model, TrainerConfig(n_workers=2, remat=False,
                                         use_pallas=False))
    assert not plain.opt.cfg.nonfinite_guard
    data = SyntheticLM(cfg, ShapeSpec("t", "train", 32, 4), n_workers=2,
                       seed=0)
    state = tr.init(key)
    step = jax.jit(tr.make_step())
    losses = []
    for i in range(6):
        state, aux = step(state, data.batch_at(i), 0.01)
        losses.append(float(aux["loss"]))
    assert all(np.isfinite(l) for l in losses)
    _assert_state_finite(state)
