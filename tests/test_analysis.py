"""repro.analysis (DESIGN.md §12): the lint engine's unit surface.

Seeded-violation coverage: each rule gets a deliberately broken
hand-written module (extra collective, forced upcast, dropped donation,
replicated bucket dot, host callback, drifted hash) and the assertion is
two-sided — the violation trips *its* rule, and no other rule
(error/warn level) fires on the same artifact. The lint CLI itself is
exercised end-to-end by the slow matrix test and CI's lint job.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import hlo_ir
from repro.analysis.baseline import (hashes_comparable, load_baseline,
                                     save_baseline)
from repro.analysis.program import (BucketAudit, ProgramArtifact,
                                    canonical_hash, entry_param_bytes,
                                    input_output_aliases)
from repro.analysis.rules import (RULES, equality_findings, run_rules,
                                  wire_budget_findings)
from repro.core.muon import WireBudget
from repro.launch.hlo_analysis import attribute_u8_directions
from repro.launch.hlo_cost import analyze


def _module(body_lines, header="", extra_comps=""):
    body = "\n".join("  " + ln for ln in body_lines)
    return (f"HloModule m{header}\n\n{extra_comps}"
            f"ENTRY main {{\n{body}\n}}\n")


def _hard(findings):
    """error/warn findings only — the levels that fail the lint."""
    return [f for f in findings if f.level in ("error", "warn")]


# ------------------------------------------------------- hlo_ir re-exports

def test_hlo_cost_reexports_shared_ir():
    """Satellite: launch.hlo_cost's parser IS analysis.hlo_ir (one
    parser, two consumers — no drift possible)."""
    from repro.launch import hlo_cost

    assert hlo_cost.parse_module is hlo_ir.parse_module
    assert hlo_cost.Computation is hlo_ir.Computation
    assert hlo_cost.Instr is hlo_ir.Instr


def test_parse_handwritten_module():
    comps = hlo_ir.parse_module(_module([
        "p0 = u8[1024]{0} parameter(0)",
        "ROOT c = u8[1024]{0} copy(p0)",
    ]))
    entry = hlo_ir.entry_name(comps)
    comp = comps[entry]
    assert comp.sizes["p0"] == 1024
    assert [hlo_ir.base_op(i.op) for i in comp.instrs] == \
        ["parameter", "copy"]


# ------------------------------------------------------- orphan regression

ORPHAN_HLO = _module([
    "p0 = u8[1024]{0} parameter(0)",
    "ags = (u8[1024]{0}, u8[4096]{0}) all-gather-start(p0), dimensions={0}",
    "ROOT c = u8[1024]{0} copy(p0)",
])


def test_orphan_gather_start_not_attributed():
    """Regression (satellite 2): an async all-gather-start whose -done
    is missing (truncated module text) used to window to the end of the
    computation and byte-match a direction as if it completed. It must
    surface as an orphan instead — unmatched, its expected size still
    missing."""
    pairs = analyze(ORPHAN_HLO)["coll_pairs"]
    assert len(pairs) == 1 and pairs[0]["orphan"] is True
    split = attribute_u8_directions(pairs, [1024], [])
    assert split["w2s"] == {"bytes": 0, "count": 0}
    assert split["missing"]["w2s"] == [1024]
    assert split["missing"]["orphan"] == [1024]
    budget = WireBudget(pack_w2s=True, pack_s2w=False, n_stages=1,
                        w2s_sizes=(1024,), s2w_sizes=())
    msgs = [f.message for f in wire_budget_findings(pairs, budget, "t")]
    assert any("without a matching done" in m for m in msgs), msgs


# ------------------------------------------------- seeded: wire-budget

def _wire_art(gather_operands, budget):
    lines = []
    for i, nbytes in enumerate(gather_operands):
        lines.append(f"p{i} = u8[{nbytes}]{{0}} parameter({i})")
        lines.append(f"ag{i} = u8[{nbytes * 4}]{{0}} all-gather(p{i}), "
                     "replica_groups={{0,1,2,3}}, dimensions={0}")
    lines.append("ROOT r = u8[8]{0} constant({0})")
    return ProgramArtifact(cell="seed", hlo_text=_module(lines),
                           budget=budget)


def test_seeded_extra_collective_trips_only_wire_budget():
    budget = WireBudget(pack_w2s=True, pack_s2w=False, n_stages=1,
                        w2s_sizes=(1024,), s2w_sizes=())
    # green path: exactly the budget's population -> no findings
    assert _hard(run_rules(_wire_art([1024], budget))) == []
    # seeded: one extra u8 all-gather nobody budgeted
    bad = _hard(run_rules(_wire_art([1024, 512], budget)))
    assert {f.rule for f in bad} == {"wire-budget"}
    assert any("no wire direction expects" in f.message for f in bad)


def test_seeded_missing_collective_trips_only_wire_budget():
    budget = WireBudget(pack_w2s=True, pack_s2w=False, n_stages=2,
                        w2s_sizes=(1024, 512), s2w_sizes=())
    bad = _hard(run_rules(_wire_art([1024], budget)))
    assert {f.rule for f in bad} == {"wire-budget"}
    assert any("1 u8 all-gathers byte-matched, expected 2" in f.message
               for f in bad)


# ------------------------------------------------- seeded: dtype-upcast

def test_seeded_u8_float_upcast_trips_only_dtype_rule():
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = u8[4096]{0} parameter(0)",
        "ROOT c = f32[4096]{0} convert(p0)",
    ]))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"dtype-upcast"}
    assert any("u8 -> f32" in f.message for f in bad)
    # small converts (indices, flags) stay legal
    ok = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = u8[16]{0} parameter(0)",
        "ROOT c = f32[16]{0} convert(p0)",
    ]))
    assert _hard(run_rules(ok)) == []


def test_seeded_f64_trips_only_dtype_rule():
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = f32[64]{0} parameter(0)",
        "ROOT c = f64[64]{0} convert(p0)",
    ]))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"dtype-upcast"}
    assert any("f64" in f.message for f in bad)


def test_seeded_state_dtype_drift_trips_only_dtype_rule():
    art = ProgramArtifact(
        cell="seed",
        hlo_text=_module(["ROOT p0 = bf16[64]{0} parameter(0)"]),
        state_in=(("['x']", (64,), "bfloat16"),),
        state_out=(("['x']", (64,), "float32"),))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"dtype-upcast"}
    assert any("drifts bfloat16 -> float32" in f.message for f in bad)


# ---------------------------------------------------- seeded: donation

_DONATE_LINES = [
    "p0 = f32[16384,16]{1,0} parameter(0)",   # 1 MiB state leaf
    "p1 = f32[16384,16]{1,0} parameter(1)",   # 1 MiB state leaf
    "p2 = f32[8]{0} parameter(2)",            # batch
    "ROOT t = (f32[16384,16]{1,0}, f32[16384,16]{1,0}) tuple(p0, p1)",
]
_STATE2 = ((" ['x']", (16384, 16), "float32"),
           (" ['m']", (16384, 16), "float32"))


def test_seeded_dropped_donation_trips_only_donation_rule():
    # only leaf 1 aliased; leaf 0's MiB stays double-buffered
    art = ProgramArtifact(
        cell="seed",
        hlo_text=_module(
            _DONATE_LINES,
            header=", input_output_alias={ {1}: (1, {}, may-alias) }"),
        donate=True, state_in=_STATE2, state_out=_STATE2, n_flat_args=3)
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"donation"}
    assert any("not input/output aliased" in f.message for f in bad)
    # green path: both large leaves aliased
    ok = ProgramArtifact(
        cell="seed",
        hlo_text=_module(
            _DONATE_LINES,
            header=", input_output_alias={ {0}: (0, {}, may-alias), "
                   "{1}: (1, {}, may-alias) }"),
        donate=True, state_in=_STATE2, state_out=_STATE2, n_flat_args=3)
    assert _hard(run_rules(ok)) == []


def test_alias_and_param_parsers():
    text = _module(
        _DONATE_LINES,
        header=", input_output_alias={ {0}: (0, {}, may-alias), "
               "{1}: (1, {}, may-alias) }")
    assert input_output_aliases(text) == {0, 1}
    pb = entry_param_bytes(hlo_ir.parse_module(text))
    assert pb == {0: 16384 * 16 * 4, 1: 16384 * 16 * 4, 2: 32}


# ------------------------------------------------- seeded: replication

_NS_DOT = ("d{i} = f32[8,64,64]{{2,1,0}} dot(x{i}, x{i}), "
           "lhs_contracting_dims={{2}}, rhs_contracting_dims={{1}}")
_BUCKET = BucketAudit((8, 64, 64), (2, 64, 32),
                      "PartitionSpec('data', None, 'model')")


def test_seeded_replicated_bucket_dot_trips_only_replication():
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "x0 = f32[8,64,64]{2,1,0} parameter(0)",
        _NS_DOT.format(i=0),
        "ROOT r = f32[8,64,64]{2,1,0} copy(d0)",
    ]), buckets=(_BUCKET,))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"replication"}
    assert any("materialises full NS bucket stack 8x64x64" in f.message
               for f in bad)
    # the per-device shard is NOT a violation
    ok = ProgramArtifact(cell="seed", hlo_text=_module([
        "x0 = f32[2,64,32]{2,1,0} parameter(0)",
        "d0 = f32[2,64,64]{2,1,0} dot(x0, x0), "
        "lhs_contracting_dims={2}, rhs_contracting_dims={2}",
        "ROOT r = f32[2,64,64]{2,1,0} copy(d0)",
    ]), buckets=(_BUCKET,))
    assert _hard(run_rules(ok)) == []


def test_replication_ignores_while_bodies():
    """The model's scan-over-layers may legitimately contain dots whose
    dims collide with a bucket stack; the walk stops at whiles."""
    extra = (
        "body {\n"
        "  bp = (f32[8,64,64]{2,1,0}) parameter(0)\n"
        "  bx = f32[8,64,64]{2,1,0} get-tuple-element(bp), index=0\n"
        "  bd = f32[8,64,64]{2,1,0} dot(bx, bx), "
        "lhs_contracting_dims={2}, rhs_contracting_dims={1}\n"
        "  ROOT br = (f32[8,64,64]{2,1,0}) tuple(bd)\n"
        "}\n\n"
        "cond {\n"
        "  cp = (f32[8,64,64]{2,1,0}) parameter(0)\n"
        "  ROOT cc = pred[] constant(false)\n"
        "}\n\n")
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = (f32[8,64,64]{2,1,0}) parameter(0)",
        "ROOT w = (f32[8,64,64]{2,1,0}) while(p0), condition=cond, "
        "body=body",
    ], extra_comps=extra), buckets=(_BUCKET,))
    assert _hard(run_rules(art)) == []


# --------------------------------------------------- seeded: host-sync

def test_seeded_host_callback_trips_only_host_sync():
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = f32[4]{0} parameter(0)",
        'ROOT cc = f32[4]{0} custom-call(p0), '
        'custom_call_target="xla_python_cpu_callback"',
    ]))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"host-sync"}
    # device custom-calls (deepseek's TopK) are not host round-trips
    ok = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = f32[4]{0} parameter(0)",
        'ROOT cc = f32[4]{0} custom-call(p0), custom_call_target="TopK"',
    ]))
    assert _hard(run_rules(ok)) == []


def test_seeded_outfeed_trips_host_sync():
    art = ProgramArtifact(cell="seed", hlo_text=_module([
        "p0 = f32[4]{0} parameter(0)",
        "tok = token[] after-all()",
        "ROOT of = token[] outfeed(p0, tok)",
    ]))
    bad = _hard(run_rules(art))
    assert {f.rule for f in bad} == {"host-sync"}


# ----------------------------------------------- seeded: lowering-drift

def test_seeded_hash_drift_trips_only_drift_rule():
    art = ProgramArtifact(cell="c", hlo_text=_module(
        ["ROOT p0 = f32[4]{0} parameter(0)"]))
    ctx = {"baseline_hashes": {"c": "0" * 16}, "hashes_comparable": True}
    bad = _hard(run_rules(art, ctx))
    assert {f.rule for f in bad} == {"lowering-drift"}
    # a jax-version mismatch gates the comparison off
    ctx["hashes_comparable"] = False
    assert _hard(run_rules(art, ctx)) == []
    # matching hash: clean
    ctx = {"baseline_hashes": {"c": art.canonical_hash},
           "hashes_comparable": True}
    assert _hard(run_rules(art, ctx)) == []


def test_canonical_hash_mods_out_ssa_names_and_metadata():
    # real dumps %-prefix every value name; uniquifier suffixes and op
    # metadata (source paths!) must not affect the fingerprint
    a = _module(['%x.1 = f32[4]{0} add(%a.2, %b.3), metadata={op_name="f" '
                 'source_file="/tmp/a.py" source_line=3}',
                 "ROOT %r.4 = f32[4]{0} copy(%x.1)"])
    b = _module(["%y.9 = f32[4]{0} add(%c.7, %d.8)",
                 "ROOT %q.5 = f32[4]{0} copy(%y.9)"])
    assert canonical_hash(a) == canonical_hash(b)
    c = _module(["%y.9 = f32[4]{0} multiply(%c.7, %d.8)",
                 "ROOT %q.5 = f32[4]{0} copy(%y.9)"])
    assert canonical_hash(a) != canonical_hash(c)
    # operand-order swaps survive the renaming (first-appearance order)
    d = _module(["%y.9 = f32[4]{0} add(%d.8, %c.7)",
                 "ROOT %q.5 = f32[4]{0} copy(%y.9)"])
    assert canonical_hash(b) == canonical_hash(d)  # args unseen before
    e = _module(["%u = f32[4]{0} negate(%c.7)",
                 "%y.9 = f32[4]{0} add(%d.8, %c.7)",
                 "ROOT %q.5 = f32[4]{0} copy(%y.9)"])
    f = _module(["%u = f32[4]{0} negate(%c.7)",
                 "%y.9 = f32[4]{0} add(%c.7, %d.8)",
                 "ROOT %q.5 = f32[4]{0} copy(%y.9)"])
    assert canonical_hash(e) != canonical_hash(f)  # a real operand swap


def test_equality_findings():
    a = ProgramArtifact(cell="a", hlo_text=_module(
        ["ROOT p0 = f32[4]{0} parameter(0)"]))
    b = ProgramArtifact(cell="b", hlo_text=_module(
        ["ROOT p0 = f32[8]{0} parameter(0)"]))
    same = ProgramArtifact(cell="a2", hlo_text=a.hlo_text)
    assert equality_findings(a, same) == []
    diff = equality_findings(a, b)
    assert len(diff) == 1 and diff[0].rule == "lowering-drift"
    assert diff[0].cell == "a~b"


# -------------------------------------------------------- budget + sink

def test_wire_budget_matches_layer_plan_accounts():
    """WireBudget's per-stage sizes must reproduce the monolithic
    WireLayout byte accounts (both directions), with one entry per
    stage — the budget is a re-slicing of Table 2, not a new account."""
    from repro.configs import get_config
    from repro.core.muon import EF21Muon, EF21MuonConfig
    from repro.models.api import abstract_params, build_model

    cfg = get_config("nanogpt-124m").reduced()
    params, metas = abstract_params(build_model(cfg))
    opt = EF21Muon(EF21MuonConfig(n_workers=4, beta=0.5,
                                  w2s="top10+natural", s2w="natural",
                                  use_pallas=False))
    budget = opt.wire_budget(params, metas, distributed=True)
    plan = opt.plan(params, metas)
    dt = opt.cfg.wire_dtype
    assert budget.pack_w2s and budget.pack_s2w
    assert budget.w2s_nbytes == plan.wire_layout(dt).total_nbytes
    assert budget.s2w_nbytes == \
        plan.wire_layout(dt, direction="s2w").total_nbytes
    assert len(budget.w2s_sizes) == len(budget.s2w_sizes) \
        == budget.n_stages
    assert budget.two_way_nbytes == budget.w2s_nbytes + budget.s2w_nbytes
    # undistributed: no collectives expected in either direction
    local = opt.wire_budget(params, metas, distributed=False)
    assert local.w2s_sizes == () and local.s2w_sizes == ()


def test_sink_lint_kind():
    from repro.obs.sink import SchemaError, validate_record

    rec = {"schema": "repro.metrics/v1", "kind": "lint",
           "rule": "wire-budget", "cell": "nanogpt-124m@4x2/default",
           "level": "error", "message": "boom", "data": {"x": 1}}
    assert validate_record(rec) == "lint"
    with pytest.raises(SchemaError):
        validate_record({"schema": "repro.metrics/v1", "kind": "lint",
                         "rule": "wire-budget"})


def test_baseline_roundtrip(tmp_path):
    p = str(tmp_path / "b.json")
    doc = save_baseline(p, {"c": "abc"}, ["r|c|m"])
    assert load_baseline(p) == doc
    assert hashes_comparable(doc)       # recorded under the running jax
    doc["jax"] = "0.0.0"
    assert not hashes_comparable(doc)
    empty = load_baseline(str(tmp_path / "missing.json"))
    assert empty["hashes"] == {} and empty["findings"] == []


def test_rule_registry_complete():
    assert set(RULES) == {"wire-budget", "replication", "dtype-upcast",
                          "donation", "host-sync", "lowering-drift"}


# -------------------------------------------------------- CLI (slow)

@pytest.mark.slow
def test_lint_cli_end_to_end(tmp_path):
    """The CLI over one real cell: first run records the baseline
    (exit 0), the re-run reproduces hashes and findings against it
    (exit 0) — lowering determinism and the allowlist workflow in one.
    A second --update-baseline after the green run must keep the
    still-firing allowlist entries (regression: it used to save only
    *unbaselined* findings, so updating on green wiped the list)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    base = str(tmp_path / "baseline.json")
    cmd = [sys.executable, "-m", "repro.analysis.lint",
           "--configs", "nanogpt-124m", "--arms", "default",
           "--baseline", base]
    cwd = os.path.join(os.path.dirname(__file__), "..")
    out = subprocess.run(cmd + ["--update-baseline"], env=env, cwd=cwd,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    doc = json.load(open(base))
    assert doc["hashes"], doc
    out2 = subprocess.run(cmd, env=env, cwd=cwd, capture_output=True,
                          text=True, timeout=900)
    assert out2.returncode == 0, out2.stdout + out2.stderr[-2000:]
    out3 = subprocess.run(cmd + ["--update-baseline"], env=env, cwd=cwd,
                          capture_output=True, text=True, timeout=900)
    assert out3.returncode == 0, out3.stdout + out3.stderr[-2000:]
    doc3 = json.load(open(base))
    assert doc3["findings"] == doc["findings"], (doc, doc3)
    assert doc3["hashes"] == doc["hashes"]
