"""repro.wire: bit-exact pack/unpack for every codec, fused-buffer
layout invariants, the lossless_wire capability flag, and a checkpoint
round-trip of full EF21 state with wire-format compressors enabled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# property tests skip individually when hypothesis is absent; the
# plain oracle tests in this file still run (see _hypothesis_compat)
from _hypothesis_compat import given, settings, st

from repro.core import compressors as C
from repro.core.error_feedback import apply_payload, ef_compress_step
from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.layerwise import LayerPlan
from repro.wire.codecs import NarrowIntCodec, RawCodec, index_domains


def _single_leaf_layout(name, shape, stack_dims=0, lmo="spectral",
                        direction="w2s"):
    params = {"p": jax.ShapeDtypeStruct(shape, jnp.float32)}
    metas = {"p": ParamMeta(lmo, 1.0, stack_dims)}
    plan = LayerPlan.build(params, metas, **{direction: name})
    return plan, plan.wire_layout(jnp.bfloat16, direction=direction)


def _tree_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _payload_for(comp, shape, key):
    wire = jnp.dtype(jnp.bfloat16)
    in_dtype = (jnp.float32 if getattr(comp, "lossless_wire", False)
                else wire)
    x = jax.random.normal(key, shape, jnp.float32).astype(in_dtype)
    state = comp.init(key, shape, wire)
    payload, _ = comp.compress(state, x)
    return payload


@given(name=st.sampled_from(sorted(C.REGISTRY)),
       m=st.integers(3, 33), n=st.integers(3, 33),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_every_codec_roundtrips_bitexact(name, m, n, seed):
    """Hypothesis: pack -> unpack is the identity, bit-for-bit, for every
    registry compressor on arbitrary (odd, tail-padding-forcing) shapes."""
    key = jax.random.key(seed)
    plan, layout = _single_leaf_layout(name, (m, n))
    comp = plan.leaves[0].w2s
    payload = jax.tree.map(lambda a: a[None],            # worker dim of 1
                           _payload_for(comp, (m, n), key))
    buf = layout.pack([payload])
    assert buf.dtype == jnp.uint8
    assert buf.shape == (1, layout.total_nbytes)
    _tree_equal(layout.unpack(buf)[0], payload)


@given(name=st.sampled_from(["top10+natural", "natural", "top10",
                             "identity"]),
       L=st.integers(1, 4), m=st.integers(3, 17), n=st.integers(3, 17),
       W=st.integers(1, 3), seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_stacked_leaf_roundtrips_bitexact(name, L, m, n, W, seed):
    """Same invariant on stacked leaves [W, L, m, n] — the codecs are
    vmapped over the worker and stack dims exactly as the step does."""
    key = jax.random.key(seed)
    plan, layout = _single_leaf_layout(name, (L, m, n), stack_dims=1)
    comp = plan.leaves[0].w2s
    keys = jax.random.split(key, W * L).reshape(W, L)
    payload = jax.vmap(jax.vmap(
        lambda k: _payload_for(comp, (m, n), k)))(keys)
    buf = layout.pack([payload])
    assert buf.shape == (W, layout.total_nbytes)
    _tree_equal(layout.unpack(buf)[0], payload)


@given(name=st.sampled_from(sorted(C.REGISTRY) + ["identity+natural"]),
       stacked=st.booleans(), L=st.integers(1, 3),
       m=st.integers(3, 33), n=st.integers(3, 33),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_s2w_direction_roundtrips_bitexact(name, stacked, L, m, n, seed):
    """Hypothesis (§9): the s2w wire leg round-trips bit-exactly for
    every registry compressor (plus identity+natural, the quantised
    Identity wrapper) on arbitrary odd shapes and stacked leaves — the
    model-update broadcast buffer carries a lead dim of 1, not
    n_workers, and the layout records its direction."""
    key = jax.random.key(seed)
    shape = (L, m, n) if stacked else (m, n)
    plan, layout = _single_leaf_layout(name, shape,
                                       stack_dims=int(stacked),
                                       direction="s2w")
    assert layout.direction == "s2w"
    comp = plan.leaves[0].s2w
    if stacked:
        keys = jax.random.split(key, L).reshape(1, L)
        payload = jax.vmap(jax.vmap(
            lambda k: _payload_for(comp, (m, n), k)))(keys)
    else:
        payload = jax.tree.map(lambda a: a[None],     # server lead dim 1
                               _payload_for(comp, (m, n), key))
    buf = layout.pack([payload])
    assert buf.dtype == jnp.uint8
    assert buf.shape == (1, layout.total_nbytes)
    _tree_equal(layout.unpack(buf)[0], payload)


@pytest.mark.parametrize("name", sorted(C.REGISTRY))
def test_registry_codec_roundtrip_fixed_odd_shape(name, key):
    """Non-hypothesis floor: every registry compressor round-trips
    bit-exactly on one odd shape (tail padding in signs and indices)."""
    shape = (13, 21)
    plan, layout = _single_leaf_layout(name, shape)
    comp = plan.leaves[0].w2s
    payload = jax.tree.map(lambda a: a[None],
                           _payload_for(comp, shape, key))
    buf = layout.pack([payload])
    assert buf.shape == (1, layout.total_nbytes)
    _tree_equal(layout.unpack(buf)[0], payload)


def test_layout_offset_table_is_static_and_contiguous():
    params = {"w": jnp.zeros((3, 16, 24)), "v": jnp.zeros((40,)),
              "e": jnp.zeros((64, 1024))}
    metas = {"w": ParamMeta("spectral", 1.0, 1),
             "v": ParamMeta("sign", 1.0, 0, compressible=False),
             "e": ParamMeta("sign", 1.0, 0)}
    plan = LayerPlan.build(params, metas, w2s="top10+natural")
    layout = plan.wire_layout(jnp.bfloat16)
    assert plan.wire_layout(jnp.bfloat16) is layout       # memoised
    pos = 0
    for spec in layout.specs:
        assert spec.offset == pos                         # contiguous
        pos += spec.region_nbytes
    assert pos == layout.total_nbytes
    # incompressible leaf ships the exact f32 diff (lossless identity)
    table = layout.describe()
    byleaf = {r["codec"]: r for r in table}
    assert "identity[raw:float32]" in byleaf
    # 64*1024 = 65536 elements -> u16 indices still suffice
    assert any(r["codec"].startswith("top10%+natural[u16") for r in table)
    # eval_shape over pack agrees with the offset table, no allocation
    structs = layout.payload_structs(n_workers=2)
    out = jax.eval_shape(layout.pack, structs)
    assert out.shape == (2, layout.total_nbytes) and out.dtype == jnp.uint8


def test_narrow_width_selection_per_domain():
    from repro.kernels.bitpack import narrow_width
    assert narrow_width(1 << 16) == 2
    assert narrow_width((1 << 16) + 1) == 3
    assert narrow_width(1 << 24) == 3
    assert narrow_width((1 << 24) + 1) == 4
    # a wide-domain TopK leaf falls back to raw int32 indices
    plan, layout = _single_leaf_layout("top10", (1 << 12, 1 << 13))
    (spec,) = layout.specs
    assert any(isinstance(c, RawCodec) and c.dtype == "int32"
               for c in spec.codecs)
    assert not any(isinstance(c, NarrowIntCodec) for c in spec.codecs)


def test_index_domains_column_topk():
    assert index_domains(C.ColumnTopK(0.1), (128, 300)) == {"indices": 300}
    assert index_domains(C.WithNatural(C.TopK(0.1)), (16, 8)) == \
        {"indices": 128}
    assert index_domains(C.Natural(), (16, 8)) == {}


def test_packed_step_equals_unpacked_step_bitexact(key):
    """The whole point: routing phase 4 through the wire buffer changes
    nothing — packed and unpacked steps produce bit-identical states."""
    params = {"w": jnp.zeros((3, 12, 16)), "v": jnp.zeros((24,))}
    metas = {"w": ParamMeta("spectral", 1.0, 1),
             "v": ParamMeta("sign", 1.0, 0, compressible=False)}
    T = jax.tree.map(lambda p: jax.random.normal(
        jax.random.fold_in(key, 3), p.shape), params)

    def gal(p, b):
        loss = sum(jnp.sum((x - t) ** 2) for x, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(T)))
        return loss, jax.tree.map(lambda x, t: 2 * (x - t), p, T)

    states = {}
    for packed in (True, False):
        opt = EF21Muon(EF21MuonConfig(
            n_workers=2, beta=0.5, w2s="top10+natural", s2w="natural",
            use_pallas=False, wire_pack=packed))
        state = opt.init(key, params, metas)
        # explicit hook: packing only engages around a reshard boundary
        fn = opt.make_step(metas, reshard_payloads=lambda t: t)
        step = jax.jit(lambda s, b, t, f=fn: f(s, gal, b, t))
        for i in range(3):
            state, _ = step(state, jnp.zeros((2, 1)), 0.01)
        states[packed] = state
    _tree_equal(states[True], states[False])


def test_wire_bytes_bookkeeping_matches_layout(key):
    opt = EF21Muon(EF21MuonConfig(n_workers=2, w2s="top10+natural"))
    params = {"w": jnp.zeros((8, 16, 32))}
    metas = {"w": ParamMeta("spectral", 1.0, 1)}
    wire = opt.wire_bytes_per_worker(params, metas)
    analytic = opt.w2s_bytes_per_worker(params, metas)
    assert wire == opt.plan(params, metas).wire_layout(
        jnp.bfloat16).total_nbytes
    # narrow indices put the wire at or below the 4-byte-index account
    assert 0 < wire <= analytic


# ------------------------------------------------- lossless_wire satellite

def test_identity_subclass_stays_lossless(key):
    """The capability flag (not a type-name check) drives the EF wire
    dtype: an Identity subclass must keep the exact f32 path."""
    class LoggedIdentity(C.Identity):
        pass

    comp = LoggedIdentity()
    assert comp.lossless_wire
    target = jax.random.normal(key, (9, 9)) * 1e-3
    payload, _, est = ef_compress_step(comp, {}, jnp.zeros((9, 9)), target)
    assert payload.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(est), np.asarray(target))


def test_with_natural_identity_end_to_end(key):
    """WithNatural(Identity): compress/decompress/payload_bytes agree
    (satellite: the payload_bytes Identity branch is now reachable)."""
    comp = C.get_compressor("identity+natural")
    assert isinstance(comp.inner, C.Identity)
    assert not comp.lossless_wire                  # the wrapper quantises
    shape = (13, 21)
    n = 13 * 21
    assert comp.payload_bytes(shape, jnp.bfloat16) == n + (n + 7) // 8
    x = jax.random.normal(key, shape).astype(jnp.bfloat16)
    payload, _ = comp.compress({}, x)
    assert set(payload) == {"codes", "signs"}
    xh = comp.decompress(payload, shape, jnp.float32)
    # natural semantics: relative error <= 1/3 elementwise
    xb = np.asarray(x, np.float32)
    rel = np.abs(np.asarray(xh) - xb) / np.maximum(np.abs(xb), 1e-30)
    assert rel.max() <= 1 / 3 + 1e-2
    # EF sender/receiver invariant holds through the wrapper
    est_s = jnp.zeros(shape)
    est_r = jnp.zeros(shape)
    payload, _, est_s = ef_compress_step(comp, {}, est_s, x.astype(jnp.float32))
    est_r = apply_payload(comp, payload, est_r)
    np.testing.assert_array_equal(np.asarray(est_s), np.asarray(est_r))


# -------------------------------------------- checkpoint round-trip (EF21)

def test_checkpoint_roundtrip_with_wire_compressors(tmp_path, key):
    """Full EF21 state (momentum, per-worker estimates, compressor state,
    EF21-P model estimates) survives a save/load round-trip bit-exactly
    with wire-format compressors on both directions, and training
    continues identically from the restored state."""
    import os

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.data import SyntheticLM
    from repro.models.api import build_model
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("granite-3-2b").reduced()
    model = build_model(cfg)
    tr = Trainer(model, TrainerConfig(n_workers=2, beta=0.5,
                                      w2s="top10+natural", s2w="natural",
                                      remat=False, use_pallas=False))
    data = SyntheticLM(cfg, ShapeSpec("t", "train", 32, 4), n_workers=2,
                       seed=0)
    state = tr.init(key)
    step = jax.jit(tr.make_step())
    state, _ = step(state, data.batch_at(0), 0.01)
    path = os.path.join(tmp_path, "ef21_wire.npz")
    save_checkpoint(path, state, step=1)
    state2, at = load_checkpoint(path, state)
    assert at == 1
    _tree_equal(state, state2)
    a, _ = step(state, data.batch_at(1), 0.01)
    b, _ = step(state2, data.batch_at(1), 0.01)
    _tree_equal(a, b)


def test_checkpoint_roundtrip_with_s2w_wire_engaged(tmp_path, key):
    """Satellite of §9: with the s2w wire leg actually ENGAGED (reshard
    hooks set, so phase 1 runs pack -> broadcast -> unpack ->
    apply_payload), the EF21-P state pair (cs_state, w) survives a
    save/load round-trip bit-exactly and training continues identically
    — the wire bytes ARE the recurrence, so a restored server must
    replay it bit-for-bit."""
    import os

    from repro.train.checkpoint import load_checkpoint, save_checkpoint

    params = {"w": jnp.zeros((3, 12, 16)), "v": jnp.zeros((24,))}
    metas = {"w": ParamMeta("spectral", 1.0, 1),
             "v": ParamMeta("sign", 1.0, 0, compressible=False)}
    T = jax.tree.map(lambda p: jax.random.normal(
        jax.random.fold_in(key, 3), p.shape), params)

    def gal(p, b):
        loss = sum(jnp.sum((x - t) ** 2) for x, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(T)))
        return loss, jax.tree.map(lambda x, t: 2 * (x - t), p, T)

    opt = EF21Muon(EF21MuonConfig(
        n_workers=2, beta=0.5, w2s="top10+natural", s2w="natural",
        use_pallas=False))
    state = opt.init(key, params, metas)
    assert state["cs_state"] is not None and state["w"] is not None
    fn = opt.make_step(metas, reshard_payloads=lambda t: t)
    step = jax.jit(lambda s, b, t, f=fn: f(s, gal, b, t))
    state, _ = step(state, jnp.zeros((2, 1)), 0.01)
    path = os.path.join(tmp_path, "s2w_wire.npz")
    save_checkpoint(path, state, step=1)
    state2, at = load_checkpoint(path, state)
    assert at == 1
    _tree_equal(state["cs_state"], state2["cs_state"])
    _tree_equal(state["w"], state2["w"])
    _tree_equal(state, state2)
    for i in range(2):
        state, _ = step(state, jnp.zeros((2, 1)), 0.01)
        state2, _ = step(state2, jnp.zeros((2, 1)), 0.01)
    _tree_equal(state, state2)
