"""Desynchronized-worker rejoin + supervised recovery (DESIGN.md §13).

The resync algebra (dist/resync.py), its optimizer integration (the
version vector, replay ring, and full-resync fallback inside the jitted
step), the §13 reception semantics, the new host-side fault clauses
(stall/crash), the supervisor state machine, and the checkpoint
durability satellites.

The pinned invariant: a worker absent across K s2w broadcasts is, after
rejoin, BIT-identical to the always-present workers — on any compressor,
because every worker applies the same broadcast byte stream through the
same ``apply_payload`` algebra, whether on time or replayed from the
ring. The lossless-wire arm additionally ties the shared estimate to the
server's iterate; the lossy arm to the EF21-P contraction bound.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as compressors_mod
from repro.core.compressors import Identity
from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.participation import Explicit, reception_mask
from repro.dist.resync import (init_resync_state, replay_masks,
                               resolve_ring_depth, ring_push,
                               serve_full_resync)
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.faults import (CRASH_EXIT, CrashFault, DropFault,
                                FaultPlan, StallFault, parse_faults)
from repro.train.supervisor import (Supervisor, SupervisorConfig,
                                    SupervisorError)


# ------------------------------------------------------------ fixtures

def _hetero(n_w=4, dim=12, seed=0):
    """Heterogeneous quadratic workers: worker j pulls toward target
    T_j, so partial participation visibly changes the trajectory."""
    key = jax.random.key(seed)
    Ts = jax.random.normal(key, (n_w, dim, dim))

    def gal(p, wb):
        t = Ts[jnp.int32(wb[0])]
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    params = jnp.zeros((dim, dim))
    metas = ParamMeta("spectral", 1.0, 0)
    batch = jnp.arange(float(n_w)).reshape(n_w, 1)
    return params, metas, gal, batch


def _run(cfg, n_steps=10, n_w=4, seed=0):
    params, metas, gal, batch = _hetero(n_w=n_w, seed=seed)
    opt = EF21Muon(cfg)
    state = opt.init(jax.random.key(seed), params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas)(s, gal, b, 0.05))
    auxes = []
    for _ in range(n_steps):
        state, aux = step(state, batch)
        auxes.append(aux)
    return state, auxes


def _resync_cfg(n_w=4, s2w="natural", resync=4, masks=None, **kw):
    part = Explicit(tuple(masks)) if masks is not None else "full"
    return EF21MuonConfig(n_workers=n_w, beta=0.5, w2s="top10", s2w=s2w,
                          use_pallas=False, participation=part,
                          resync=resync, **kw)


# ------------------------------------------------- resolve_ring_depth

def test_resolve_ring_depth_off_values():
    assert resolve_ring_depth(None) == 0
    assert resolve_ring_depth(0) == 0
    assert resolve_ring_depth(False) == 0
    assert resolve_ring_depth(4) == 4


def test_resolve_ring_depth_rejects_negative():
    with pytest.raises(ValueError):
        resolve_ring_depth(-2)


def test_resync_requires_compressing_s2w():
    params, metas, _, _ = _hetero()
    opt = EF21Muon(_resync_cfg(s2w="identity"))
    with pytest.raises(ValueError, match="resync"):
        opt.init(jax.random.key(0), params, metas)


# --------------------------------------------------- replay-mask algebra

def test_replay_masks_current_worker_applies_only_newest_slot():
    # vv == step: on-time application is the degenerate replay — only
    # the current round (slot R-1) applies
    R, n = 4, 3
    rm = replay_masks(jnp.full((n,), 7), 7, jnp.ones((n,), bool), R)
    ap = np.asarray(rm.apply)
    assert (ap[R - 1] == True).all()            # noqa: E712
    assert not ap[: R - 1].any()
    assert (np.asarray(rm.vv_new) == 8).all()
    assert int(rm.n_replayed) == 0 and int(rm.n_full) == 0
    assert int(rm.lag_max) == 0


def test_replay_masks_lagged_worker_replays_missed_rounds():
    # worker 1 at vv=5, step=7, R=4: ring holds rounds 4..7 after the
    # push; it must apply rounds 5,6,7 == slots 1,2,3
    R = 4
    vv = jnp.asarray([7, 5, 7])
    rm = replay_masks(vv, 7, jnp.ones((3,), bool), R)
    ap = np.asarray(rm.apply)
    assert (ap[:, 1] == [False, True, True, True]).all()
    assert int(rm.n_replayed) == 1 and int(rm.n_full) == 0
    assert (np.asarray(rm.vv_new) == 8).all()


def test_replay_masks_lag_beyond_ring_takes_full():
    R = 3
    vv = jnp.asarray([9, 2, 9])     # worker 1 needs round 2 < 9-(R-1)=7
    rm = replay_masks(vv, 9, jnp.ones((3,), bool), R)
    assert not np.asarray(rm.apply)[:, 1].any()
    assert np.asarray(rm.full).tolist() == [False, True, False]
    assert int(rm.n_full) == 1 and int(rm.n_replayed) == 0


def test_replay_masks_absent_worker_frozen():
    recv = jnp.asarray([True, False, True])
    rm = replay_masks(jnp.full((3,), 4), 4, recv, 2)
    assert not np.asarray(rm.apply)[:, 1].any()
    assert not bool(rm.full[1])
    assert np.asarray(rm.vv_new).tolist() == [5, 4, 5]
    assert int(rm.lag_max) == 1


def test_ring_push_rolls_oldest_out():
    ring = jnp.arange(6, dtype=jnp.uint8).reshape(3, 2)
    out = np.asarray(ring_push(ring, jnp.asarray([9, 9], jnp.uint8)))
    assert (out[:2] == np.asarray(ring)[1:]).all()
    assert (out[2] == 9).all()


def test_init_resync_state_shapes():
    st = init_resync_state(5, 3, 64)
    assert st["vv"].shape == (5,) and st["vv"].dtype == jnp.int32
    assert st["ring"].shape == (3, 64) and st["ring"].dtype == jnp.uint8


def test_init_resync_state_rejects_oversized_ring_row():
    # a packed s2w row past the XLA int32 dim limit (e.g. granite-3-2b
    # at 512 devices: 2.85 GB/round) must fail loudly with guidance,
    # not crash XLA shape inference deep in lowering
    with pytest.raises(ValueError, match="serve_full_resync"):
        init_resync_state(4, 3, 2**31)


# ------------------------------------------------ reception semantics

def test_reception_mask_ands_schedule_and_drops():
    fp = FaultPlan(n_workers=3, drops=(DropFault(2, 0, 10),))
    spec = Explicit(((1, 0, 1),))
    m = np.asarray(reception_mask(spec, 3, 0, faults=fp))
    assert m.tolist() == [True, False, False]


# ------------------------------------------- optimizer-level invariant

ABSENT, K = 1, 3   # worker 1 misses K consecutive broadcasts


def _absence_masks(n_w=4, start=3, k=K):
    full = (1,) * n_w
    gone = tuple(0 if j == ABSENT else 1 for j in range(n_w))
    return [full] * start + [gone] * k + [full] * 8


def test_rejoin_within_ring_is_bit_identical_lossy():
    # lag K <= R: replay. The pinned §13 invariant — after rejoin every
    # worker's W estimate is BIT-equal to the server's (hence to every
    # always-present worker's), on a lossy compressor.
    state, auxes = _run(_resync_cfg(resync=4, masks=_absence_masks()),
                        n_steps=12)
    assert sum(int(a["resync_replayed"]) for a in auxes) >= 1
    assert sum(int(a["resync_full"]) for a in auxes) == 0
    w = np.asarray(state["w"])
    for j in range(4):
        assert np.array_equal(np.asarray(state["w_w"][j]), w), j
    # lag telemetry: grows during the absence, returns to 0 after
    lags = [int(a["version_lag_max"]) for a in auxes]
    assert max(lags) == K and lags[-1] == 0


def test_rejoin_within_ring_is_bit_identical_lossless():
    # same invariant on a lossless wire: registry-aliased Identity
    # subclass, so s2w != "identity" (the resync guard is a string
    # check) while the leg itself is exact
    compressors_mod.REGISTRY.setdefault(
        "identity-wire", lambda: type("IdentityWire", (Identity,), {})())
    state, auxes = _run(
        _resync_cfg(s2w="identity-wire", resync=4,
                    masks=_absence_masks()), n_steps=12)
    assert sum(int(a["resync_replayed"]) for a in auxes) >= 1
    w = np.asarray(state["w"])
    for j in range(4):
        assert np.array_equal(np.asarray(state["w_w"][j]), w), j
    # the lossless leg ties W to the server's iterate up to exactly one
    # LMO step of lag (W is advanced before X moves): here the step is
    # spectral-LMO with radius t, so ||x - w||_F <= t * sqrt(dim)
    x = np.asarray(state["x"])
    assert np.linalg.norm(x - w) <= 0.05 * np.sqrt(x.shape[-1]) + 1e-5


def test_lossy_rejoin_within_ef_bound():
    # EF21-P keeps ||X - W|| bounded on the lossy arm too — weaker than
    # the lossless tie, but the drift must stay comparable to the
    # always-present run's compression error, not grow with absence
    base, _ = _run(_resync_cfg(resync=4), n_steps=12)
    state, _ = _run(_resync_cfg(resync=4, masks=_absence_masks()),
                    n_steps=12)
    drift = np.linalg.norm(np.asarray(state["x"]) - np.asarray(state["w"]))
    base_drift = np.linalg.norm(
        np.asarray(base["x"]) - np.asarray(base["w"]))
    assert np.isfinite(drift)
    assert drift <= 4.0 * base_drift + 1e-6


def test_lag_beyond_ring_takes_full_resync():
    # absence of 6 rounds > R=3: replay impossible, full W copy instead
    masks = _absence_masks(start=2, k=6)
    state, auxes = _run(_resync_cfg(resync=3, masks=masks), n_steps=12)
    assert sum(int(a["resync_full"]) for a in auxes) == 1
    assert sum(int(a["resync_replayed"]) for a in auxes) == 0
    w = np.asarray(state["w"])
    for j in range(4):
        assert np.array_equal(np.asarray(state["w_w"][j]), w), j


def test_resync_off_leaves_state_and_aux_clean():
    state, auxes = _run(EF21MuonConfig(
        n_workers=4, beta=0.5, w2s="top10", s2w="natural",
        use_pallas=False), n_steps=3)
    assert "w_w" not in state and "resync" not in state
    assert "resync_replayed" not in auxes[0]
    assert "version_lag_max" not in auxes[0]


def test_resync_metrics_surface():
    _, auxes = _run(_resync_cfg(resync=2, metrics=True), n_steps=2)
    names = auxes[0]["metrics"].names()
    for want in ("part/worker_version_lag_max", "resync/replayed",
                 "resync/full"):
        assert want in names, want


def test_resync_survives_all_absent_step():
    # every worker misses a round: global skip advances W and the ring;
    # the rejoin replays that round to everyone and stays bit-consistent
    masks = [(1, 1, 1, 1), (0, 0, 0, 0), (1, 1, 1, 1)]
    state, auxes = _run(_resync_cfg(resync=4, masks=masks), n_steps=9)
    w = np.asarray(state["w"])
    for j in range(4):
        assert np.array_equal(np.asarray(state["w_w"][j]), w), j


# --------------------------------------------------- serve_full_resync

def test_serve_full_resync_round_trips(tmp_path):
    state, _ = _run(_resync_cfg(resync=2), n_steps=2)
    path = str(tmp_path / "ck")
    save_checkpoint(path, state, step=2)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    w, version = serve_full_resync(path, like)
    assert version == 2
    np.testing.assert_array_equal(np.asarray(w), np.asarray(state["w"]))


def test_serve_full_resync_rejects_non_optimizer_tree(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"params": np.zeros(3)}, step=1)
    with pytest.raises(ValueError, match="no 'x' entry"):
        serve_full_resync(path, {"params": np.zeros(3)})


# -------------------------------------------------- fault grammar (§13)

def test_parse_stall_round_trip():
    fp = parse_faults("stall:w=1:steps=5-7:ms=250", n_workers=4)
    assert fp.stalls == (StallFault(1, 5, 7, ms=250),)
    assert fp.stall_ms(5) == 250 and fp.stall_ms(7) == 0
    assert fp.stall_ms(5, attempt=1) == 0     # retries skip the stall
    assert bool(fp.active_any(6)) and not bool(fp.active_any(8))


def test_parse_stall_default_ms():
    fp = parse_faults("stall:w=0:steps=3", n_workers=2)
    assert fp.stalls[0].ms == 1000
    assert fp.stalls[0].start == 3 and fp.stalls[0].stop == 4


def test_parse_crash_round_trip():
    fp = parse_faults("crash:step=9", n_workers=2)
    assert fp.crashes == (CrashFault(9),)
    assert fp.crashes[0].start == 9 and fp.crashes[0].stop == 10
    assert bool(fp.active_any(9)) and not bool(fp.active_any(10))


def test_parse_mixed_clauses_with_host_faults():
    fp = parse_faults(
        "drop:w=1:steps=2-4,stall:w=0:steps=5:ms=50,crash:step=8",
        n_workers=3)
    assert len(fp.drops) == 1 and len(fp.stalls) == 1
    assert len(fp.crashes) == 1


@pytest.mark.parametrize("bad", [
    "stall:w=9:steps=1:ms=5",   # worker out of range
    "stall:w=0:steps=1:ms=0",   # non-positive stall
    "crash:steps=3",            # crash takes step=, not steps=
    "stall:w=0",                # missing steps
])
def test_parse_host_faults_reject(bad):
    with pytest.raises(ValueError):
        parse_faults(bad, n_workers=4)


def test_host_crash_gated_on_resumed_runs():
    fp = parse_faults("crash:step=4", n_workers=2)
    fp.host_crash(4, start_step=2)   # resumed run: must NOT exit
    fp.host_crash(3, start_step=0)   # wrong step: no exit
    assert CRASH_EXIT == 43


def test_host_stall_sleeps_and_reports(monkeypatch):
    import repro.train.faults as faults_mod
    slept = []
    monkeypatch.setattr(faults_mod.time, "sleep",
                        lambda s: slept.append(s))
    fp = parse_faults("stall:w=0:steps=2:ms=80", n_workers=1)
    assert fp.host_stall(2) == 80 and slept == [0.08]
    assert fp.host_stall(2, attempt=1) == 0 and len(slept) == 1


# ---------------------------------------------------------- supervisor

class _ListWriter:
    def __init__(self):
        self.records = []

    def write(self, rec_kind, **fields):
        self.records.append({"rec_kind": rec_kind, **fields})


def test_supervisor_passthrough_without_watchdog():
    sup = Supervisor(SupervisorConfig())
    result, rs, rstep = sup.run_step(lambda s, b: (s + b, {}), 1, 2,
                                     step=0)
    assert result == (3, {}) and rs is None and rstep is None
    assert sup.retries == 0


def test_supervisor_timeout_then_retry_succeeds():
    fp = parse_faults("stall:w=0:steps=5:ms=10000", n_workers=1)
    w = _ListWriter()
    sup = Supervisor(SupervisorConfig(step_timeout_s=0.1, max_retries=2,
                                      backoff_base_s=0.01), writer=w)
    result, rs, rstep = sup.run_step(lambda s: s * 2, 21, step=5,
                                     faults=fp)
    assert result == 42 and rs is None and rstep is None
    assert sup.retries == 1
    assert [r["event"] for r in w.records] == ["timeout"]
    assert all(r["rec_kind"] == "recovery" for r in w.records)
    assert w.records[0]["step"] == 5 and w.records[0]["attempt"] == 0


def test_supervisor_transient_exception_retries():
    attempts = []

    def flaky(state):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return state

    sup = Supervisor(SupervisorConfig(max_retries=3, backoff_base_s=0.0))
    result, _, _ = sup.run_step(flaky, "ok", step=1)
    assert result == "ok" and sup.retries == 2


def test_supervisor_exhaustion_raises():
    w = _ListWriter()
    sup = Supervisor(SupervisorConfig(max_retries=1, backoff_base_s=0.0),
                     writer=w)
    with pytest.raises(SupervisorError, match="after 2 attempt"):
        sup.run_step(lambda s: 1 / 0, None, step=3)
    assert [r["event"] for r in w.records] == ["retry", "retry",
                                               "gave_up"]


def test_supervisor_reloads_last_good_checkpoint(tmp_path):
    path = str(tmp_path / "ck")
    good = {"x": np.arange(4.0, dtype=np.float32)}
    save_checkpoint(path, good, step=6)
    w = _ListWriter()
    sup = Supervisor(
        SupervisorConfig(max_retries=0, checkpoint_path=path),
        writer=w, state_like={"x": np.zeros(4, np.float32)})

    def bad(state):
        raise RuntimeError("device poisoned")

    result, rs_state, rs_step = sup.run_step(bad, None, step=9)
    # the stored checkpoint step IS the next step to execute
    assert result is None and rs_step == 6
    np.testing.assert_allclose(np.asarray(rs_state["x"]), good["x"])
    assert sup.reloads == 1
    assert [r["event"] for r in w.records] == ["retry", "reload"]
    # a second failure with no forward progress must raise, not loop
    with pytest.raises(SupervisorError):
        sup.run_step(bad, None, step=9)


def test_supervisor_maybe_checkpoint_cadence(tmp_path):
    path = str(tmp_path / "ck")
    sup = Supervisor(SupervisorConfig(checkpoint_path=path,
                                      checkpoint_every=4))
    assert not sup.maybe_checkpoint({"x": np.zeros(2)}, 0)
    assert sup.maybe_checkpoint({"x": np.ones(2)}, 3)    # (3+1) % 4 == 0
    tree, step = load_checkpoint(path, {"x": np.zeros(2, np.float32)})
    # stored step = next step to execute (the CLI resume convention)
    assert step == 4 and np.asarray(tree["x"]).tolist() == [1.0, 1.0]


def test_supervisor_config_validation():
    with pytest.raises(ValueError):
        SupervisorConfig(max_retries=-1)
    with pytest.raises(ValueError):
        SupervisorConfig(step_timeout_s=0.0)


# ------------------------------------------- checkpoint satellites

def test_checkpoint_legacy_bare_archive_rotates_to_prev(tmp_path):
    # a pre-".npz" run left its archive at the bare path; a fresh save
    # must rotate it aside, or load_checkpoint prefers the stale bare
    # file forever
    bare = str(tmp_path / "ck")
    with open(bare, "wb") as f:
        np.savez(f, **{"x": np.zeros(3), "__step__": np.asarray(1)})
    save_checkpoint(bare, {"x": np.ones(3, np.float32)}, step=5)
    assert not os.path.exists(bare)
    assert os.path.exists(bare + ".npz") and os.path.exists(
        bare + ".npz.prev")
    tree, step = load_checkpoint(bare, {"x": np.zeros(3, np.float32)})
    assert step == 5 and np.asarray(tree["x"]).tolist() == [1.0] * 3


def test_checkpoint_publish_fsyncs_parent_dir(tmp_path, monkeypatch):
    import repro.train.checkpoint as ck
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(ck.os, "fsync",
                        lambda fd: (synced.append(fd), real_fsync(fd)))
    save_checkpoint(str(tmp_path / "ck"), {"x": np.zeros(2)}, step=0)
    # one fsync for the tmp file, one for the parent directory
    assert len(synced) == 2
