"""Shape-bucketed batched Newton-Schulz (DESIGN.md §7): bucket formation,
stack/unstack exactness, bucketed-vs-per-leaf step bit-equality on the
jnp path, and the dispatch-count regression the whole refactor exists
for (ns_steps x n_buckets fused pallas_calls instead of
3 x ns_steps x n_spectral_leaves)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.lmo import lmo_direction, lmo_direction_batched
from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.layerwise import LayerPlan
from repro.kernels import ref
from repro.kernels.ops import (count_ns_dispatches, newton_schulz,
                               newton_schulz_batched)
from repro.models.api import abstract_params, build_model


# --------------------------------------------------------- a small test tree

def _tiny_tree(key):
    """Hand-sized params/metas covering every bucketing case: same-shape
    group, transposed pair sharing a bucket, a stacked leaf folding into
    the batch dim, and non-spectral leaves left to the per-leaf path."""
    ks = jax.random.split(key, 6)
    params = {
        "wq": jax.random.normal(ks[0], (48, 32)),
        "wk": jax.random.normal(ks[1], (48, 32)),
        "w_in": jax.random.normal(ks[2], (32, 80)),
        "w_out": jax.random.normal(ks[3], (80, 32)),
        "blocks": jax.random.normal(ks[4], (3, 48, 32)),
        "bias": jax.random.normal(ks[5], (32,)),
    }
    metas = {
        "wq": ParamMeta("spectral", 1.0, 0),
        "wk": ParamMeta("spectral", 1.0, 0),
        "w_in": ParamMeta("spectral", 1.5, 0),
        "w_out": ParamMeta("spectral", 1.0, 0),
        "blocks": ParamMeta("spectral", 2.0, 1),
        "bias": ParamMeta("sign", 1.0, 0, compressible=False),
    }
    return params, metas


def test_bucket_formation(key):
    params, metas = _tiny_tree(key)
    plan = LayerPlan.build(params, metas)
    buckets = plan.ns_buckets()
    assert buckets is plan.ns_buckets()          # memoised
    by_shape = {b.shape: b for b in buckets}
    assert set(by_shape) == {(32, 48), (32, 80)}
    b1 = by_shape[(32, 48)]                       # canonical m <= n
    # treedef (dict-key) order: bias, blocks, w_in, w_out, wk, wq
    names = [plan.leaves[i].shape for i in b1.leaf_ids]
    assert b1.batch == 5                          # 3 (stack) + wk + wq
    assert b1.counts == (3, 1, 1)
    assert all(b1.transposes)                     # all stored [48, 32]
    assert b1.radius_scales == (2.0, 2.0, 2.0, 1.0, 1.0)
    assert names == [(3, 48, 32), (48, 32), (48, 32)]
    b2 = by_shape[(32, 80)]
    assert b2.batch == 2 and b2.transposes == (False, True)
    assert b2.radius_scales == (1.5, 1.0)
    # the sign vector is not bucketed
    bucketed = {i for b in buckets for i in b.leaf_ids}
    vector_ids = {i for i, lp in enumerate(plan.leaves)
                  if lp.meta.lmo != "spectral"}
    assert bucketed.isdisjoint(vector_ids)


def test_stack_unstack_roundtrip_exact(key):
    params, metas = _tiny_tree(key)
    plan = LayerPlan.build(params, metas)
    flat = plan.flatten(params)
    for b in plan.ns_buckets():
        stacked = b.stack([flat[i] for i in b.leaf_ids])
        assert stacked.shape == (b.batch,) + b.shape
        back = b.unstack(stacked)
        for i, piece in zip(b.leaf_ids, back):
            np.testing.assert_array_equal(np.asarray(piece),
                                          np.asarray(flat[i]))


def test_stack_mixed_dtypes_names_leaves(key):
    """Mixed leaf dtypes fail BEFORE any reshape work, and the TypeError
    names the offending leaf_ids (a trace-time phase-5 failure must point
    at leaves, not anonymous parts)."""
    params, metas = _tiny_tree(key)
    plan = LayerPlan.build(params, metas)
    b = plan.ns_buckets()[0]
    flat = plan.flatten(params)
    leaves = [flat[i] for i in b.leaf_ids]
    leaves[1] = leaves[1].astype(jnp.bfloat16)
    with pytest.raises(TypeError) as ei:
        b.stack(leaves)
    msg = str(ei.value)
    assert f"leaf {b.leaf_ids[1]}" in msg and "bfloat16" in msg
    # an explicit dtype= unifies instead of raising
    assert b.stack(leaves, dtype=jnp.float32).dtype == jnp.float32


def test_bucket_pspecs_on_mesh(key):
    """Mesh-aware buckets carry the ns_bucket_pspec — and shape groups
    sub-split by canonical TP orientation, so a transposed up/down pair
    (whose model axes land on opposite canonical dims) still runs
    model-sharded instead of replicated."""
    from test_sharding import FakeMesh

    params, metas = _tiny_tree(key)
    plan = LayerPlan.build(params, metas)
    mesh = FakeMesh(data=5, model=4)
    buckets = plan.ns_buckets(mesh=mesh)
    assert buckets is plan.ns_buckets(mesh=mesh)     # memoised per mesh
    assert plan.ns_buckets() != buckets              # and keyed off None
    by_key = {(b.shape, b.pspec): b for b in buckets}
    # (32, 48): all members transposed, model on canonical rows (48->32
    # transpose puts the divisible 32-dim first), batch 5 == data
    b1 = by_key[((32, 48), jax.sharding.PartitionSpec("data", "model",
                                                      None))]
    assert b1.batch == 5
    # (32, 80): w_in [32, 80] keeps model on cols, w_out [80, 32]
    # transposes it onto rows -> two orientation sub-buckets of batch 1
    shapes32_80 = [b for b in buckets if b.shape == (32, 80)]
    assert len(shapes32_80) == 2
    assert {b.pspec for b in shapes32_80} == {
        jax.sharding.PartitionSpec(None, "model", None),
        jax.sharding.PartitionSpec(None, None, "model")}
    # off-mesh build keeps the merged buckets (and no pspec)
    assert all(b.pspec is None for b in plan.ns_buckets())
    assert len(plan.ns_buckets()) == 2


@given(m=st.integers(4, 40), n=st.integers(4, 40), stack=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_stack_unstack_roundtrip_property(m, n, stack, seed):
    """stack -> unstack is the identity for arbitrary orientations and
    stack depths (transpose + reshape only, no arithmetic)."""
    k = jax.random.key(seed)
    params = {"a": jax.random.normal(k, (m, n)),
              "b": jax.random.normal(k, (n, m)),
              "s": jax.random.normal(k, (stack, m, n))}
    metas = {n_: ParamMeta("spectral", 1.0, 1 if n_ == "s" else 0)
             for n_ in params}
    plan = LayerPlan.build(params, metas)
    buckets = plan.ns_buckets()
    assert sum(b.batch for b in buckets) == stack + 2
    flat = plan.flatten(params)
    for b in buckets:
        back = b.unstack(b.stack([flat[i] for i in b.leaf_ids]))
        for i, piece in zip(b.leaf_ids, back):
            np.testing.assert_array_equal(np.asarray(piece),
                                          np.asarray(flat[i]))


# ------------------------------------------------- jnp-path bit equivalence

def test_batched_ref_bit_matches_per_slice(key):
    """newton_schulz_batched_ref == per-slice newton_schulz_ref, bitwise,
    for canonical (m <= n) stacks — the invariant the step equivalence
    rests on."""
    per_slice = jax.jit(lambda x: ref.newton_schulz_ref(x, steps=5))
    for shape in [(4, 96, 160), (3, 64, 64), (2, 13, 77)]:
        g = jax.random.normal(key, shape, jnp.float32)
        got = jax.jit(lambda x: ref.newton_schulz_batched_ref(x, steps=5))(g)
        want = jnp.stack([per_slice(g[i]) for i in range(shape[0])])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lmo_direction_batched_bit_matches_per_slice(key):
    g = jax.random.normal(key, (3, 48, 64), jnp.float32)
    got = jax.jit(lambda x: lmo_direction_batched(x, use_pallas=False))(g)
    per_slice = jax.jit(
        lambda x: lmo_direction(x, "spectral", use_pallas=False))
    want = jnp.stack([per_slice(g[i]) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError):
        lmo_direction_batched(g, kind="sign")
    with pytest.raises(ValueError):
        lmo_direction_batched(g[0])


def _quadratic_grad(params, batch):
    loss = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - batch))
               for p in jax.tree.leaves(params))
    grads = jax.tree.map(
        lambda p: 2.0 * (p.astype(jnp.float32) - batch), params)
    return loss, grads


def test_bucketed_step_bit_equal_per_leaf(key):
    """EF21-Muon step with ns_bucketing on == off, bit-for-bit, on the
    jnp path (the acceptance invariant: bucketing is a pure dispatch
    transformation)."""
    params, metas = _tiny_tree(key)
    batch = jnp.ones((2, 1)) * 0.1     # [n_workers, ...] broadcastable
    states = {}
    for bucketing in (True, False):
        opt = EF21Muon(EF21MuonConfig(n_workers=2, w2s="top10",
                                      ns_bucketing=bucketing))
        state = opt.init(key, params, metas)
        step = opt.make_step(metas)
        state, aux = jax.jit(
            lambda s, b: step(s, _quadratic_grad, b, 0.05))(state, batch)
        assert np.isfinite(float(aux["loss"]))
        states[bucketing] = state
    for field in ("x", "g_server", "g_w"):
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                            states[True][field], states[False][field])
        assert all(jax.tree.leaves(same)), (field, same)


# ------------------------------------------------ dispatch-count regression

def test_step_dispatch_count_regression(key):
    """The HLO-level win, pinned at trace level: with ns_bucketing the
    step emits at most ns_steps x n_buckets NS pallas_calls; without it,
    ns_steps x n_spectral_leaves (fused per-leaf); the pre-fusion chain
    was 3 x ns_steps x n_spectral_leaves (pinned in
    test_unfused_chain_dispatch_count)."""
    params, metas = _tiny_tree(key)
    batch = jnp.ones((1, 1)) * 0.1
    counts = {}
    for bucketing in (True, False):
        opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s="top10",
                                      use_pallas=True,
                                      ns_bucketing=bucketing))
        state = opt.init(key, params, metas)
        step = opt.make_step(metas)
        jaxpr = jax.make_jaxpr(
            lambda s, b: step(s, _quadratic_grad, b, 0.05))(state, batch)
        counts[bucketing] = count_ns_dispatches(jaxpr.jaxpr)
    plan = LayerPlan.build(params, metas)
    n_buckets = len(plan.ns_buckets())
    n_spectral = sum(1 for lp in plan.leaves if lp.meta.lmo == "spectral")
    ns_steps = 5
    assert counts[True] <= ns_steps * n_buckets, counts
    assert counts[False] == ns_steps * n_spectral, counts
    assert counts[True] < counts[False]


def test_unfused_chain_dispatch_count(key):
    """fused=False preserves the pre-fusion 3-calls-per-iteration chain
    (the A/B baseline the ISSUE counts against)."""
    g = jnp.zeros((96, 160))
    for fused, expect in ((False, 3 * 5), ("auto", 5)):
        jaxpr = jax.make_jaxpr(lambda x: newton_schulz(
            x, steps=5, use_pallas=True, fused=fused))(g)
        assert count_ns_dispatches(jaxpr.jaxpr) == expect, fused
    jaxpr = jax.make_jaxpr(lambda x: newton_schulz_batched(
        x, steps=5, use_pallas=True))(jnp.zeros((7, 96, 160)))
    assert count_ns_dispatches(jaxpr.jaxpr) == 5   # batch rides the grid


def test_infeasible_gram_falls_back_to_chain(key):
    """Slices whose [m, m] gram exceeds the fused VMEM budget fall back
    to the three-call chain instead of a miscompiled kernel."""
    from repro.kernels.newton_schulz import fused_ns_feasible
    assert fused_ns_feasible(768, 128, 4)
    assert not fused_ns_feasible(4096, 128, 4)
    g = jnp.zeros((4096, 4224))
    jaxpr = jax.make_jaxpr(lambda x: newton_schulz(
        x, steps=2, use_pallas=True))(g)
    assert count_ns_dispatches(jaxpr.jaxpr) == 3 * 2


@pytest.mark.slow
def test_nanogpt_step_dispatch_count():
    """Acceptance pin on the paper's model: a traced nanogpt-124m step
    with ns_bucketing emits at most ns_steps x n_buckets NS kernels
    (benchmarks/ns_bench.py records the same numbers in BENCH_ns.json)."""
    cfg = get_config("nanogpt-124m")
    model = build_model(cfg)
    shapes, metas = abstract_params(model)
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s="top10",
                                  use_pallas=True, ns_bucketing=True))
    state = opt.init(jax.random.key(0), params, metas)
    step = opt.make_step(metas)

    def gl(p, batch):
        return jax.value_and_grad(lambda q: model.loss(q, batch))(p)

    batch = {"tokens": jnp.zeros((1, 1, 16), jnp.int32),
             "labels": jnp.zeros((1, 1, 16), jnp.int32)}
    jaxpr = jax.make_jaxpr(lambda s, b: step(s, gl, b, 0.01))(state, batch)
    plan = opt.plan(params, metas)
    n_buckets = len(plan.ns_buckets())
    assert count_ns_dispatches(jaxpr.jaxpr) <= 5 * n_buckets


@pytest.mark.slow
def test_spmd_bucketing_ab_flop_ratio_and_equality():
    """The sharding-awareness acceptance, on a real 8-host-device mesh
    with zero1_lmo=True (subprocess; benchmarks/ns_bench.py runs the
    same A/B in the slow CI job):

      * bucketing-on / bucketing-off per-device HLO FLOPs <= 1.02x
        (the bucket concat used to drop per-leaf TP/zero-1 shardings and
        replicate the NS chain: +13.7% on the 512-chip granite dry-run);
      * the staged wire invariant (§8) holds: the staged arm lowers
        exactly K u8 payload all-gathers (K = pipeline stages), the
        monolithic and per-leaf arms exactly one, all measuring bytes
        == the WireLayout account byte-for-byte;
      * the staged arm's overlap-aware exposed-collective time is
        strictly below the monolithic arm's, and staged == monolithic
        stays bit-equal (a pure repartition) even under TP;
      * bucketed == per-leaf stays BIT-equal on the jnp path on the
        (8, 1) mesh, where sharding only ever slices batch/stack dims
        (on the (4, 2) mesh TP splits NS contractions, so cross-arm
        agreement is reduction-order-limited: ulp-level);
      * the shard_map-wrapped fused Pallas iteration matches the oracle
        on per-device sub-batches."""
    from benchmarks.ns_bench import (NS_SPMD_RATIO_BOUND,
                                     PIPELINE_EXPOSED_BOUND, spmd_ab)

    rec = spmd_ab()
    assert rec["ns_flops_ratio"] <= NS_SPMD_RATIO_BOUND, rec
    assert rec["n_stages_on"] > 1, rec
    assert rec["u8_count_on"] == rec["n_stages_on"], rec
    assert rec["u8_count_off"] == 1 and rec["u8_count_mono"] == 1, rec
    assert rec["u8_bytes_on"] == rec["u8_bytes_off"] \
        == rec["u8_bytes_mono"] == rec["wire_bytes"], rec
    assert rec["exposed_ratio"] is not None \
        and rec["exposed_ratio"] <= PIPELINE_EXPOSED_BOUND, rec
    assert rec["bit_equal_staged_mono"], rec
    assert rec["bit_equal_8x1"], rec
    assert rec["x_max_abs_diff_4x2"] < 1e-6, rec
    assert rec["shard_map_max_err"] < 2e-3, rec


# ------------------------------------------------------ padding exactness

@given(bsz=st.integers(1, 3), m=st.integers(3, 140), n=st.integers(3, 140),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_bucketed_padding_exactness_property(bsz, m, n, seed):
    """Pallas (interpret) batched NS on zero-padded non-multiple-of-128
    stacks matches the unpadded batched oracle — padding is exact through
    the fused iteration, any shape."""
    g = jax.random.normal(jax.random.key(seed), (bsz, m, n), jnp.float32)
    got = newton_schulz_batched(g, steps=3, use_pallas=True, interpret=True)
    want = ref.newton_schulz_batched_ref(g, steps=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ LRU plan cache

def test_plan_cache_keyed_on_leaf_dtypes(key):
    """Regression: the LRU key carried (treedef, shapes, metas) but not
    leaf dtypes, so switching param dtype silently reused a stale
    LayerPlan (and its memoised wire layouts / ns buckets)."""
    opt = EF21Muon(EF21MuonConfig())
    meta = {"w": ParamMeta("spectral", 1.0, 0)}
    p32 = {"w": jnp.zeros((8, 8), jnp.float32)}
    pbf = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    plan32 = opt.plan(p32, meta)
    planbf = opt.plan(pbf, meta)
    assert planbf is not plan32
    assert len(opt._plans) == 2
    # both keys stay live and identity-stable
    assert opt.plan(p32, meta) is plan32
    assert opt.plan(pbf, meta) is planbf


def test_plan_cache_lru_eviction(key):
    """Shape sweeps evict the oldest plan only — the 8 most recent stay
    live (was: wholesale clear())."""
    opt = EF21Muon(EF21MuonConfig())
    meta = ParamMeta("spectral", 1.0, 0)
    plans = []
    for i in range(9):
        p = {"w": jnp.zeros((8 + i, 8))}
        plans.append(opt.plan(p, {"w": meta}))
    assert len(opt._plans) == 8
    # 0 evicted, 1..8 still cached (identity-stable)
    assert opt.plan({"w": jnp.zeros((9, 8))}, {"w": meta}) is plans[1]
    assert opt.plan({"w": jnp.zeros((16, 8))}, {"w": meta}) is plans[8]
    new0 = opt.plan({"w": jnp.zeros((8, 8))}, {"w": meta})
    assert new0 is not plans[0]
    # the new0 insert evicted 2; cache now holds (oldest first):
    # 3, 4, 5, 6, 7, 1, 8, 0'. A hit refreshes recency: touch 3 (the
    # next eviction candidate) — the next insert then evicts 4, not 3.
    assert opt.plan({"w": jnp.zeros((11, 8))}, {"w": meta}) is plans[3]
    opt.plan({"w": jnp.zeros((99, 8))}, {"w": meta})   # evicts 4
    assert opt.plan({"w": jnp.zeros((11, 8))}, {"w": meta}) is plans[3]
    assert opt.plan({"w": jnp.zeros((12, 8))}, {"w": meta}) is not plans[4]
