"""Torn-checkpoint and flaky-sink robustness (DESIGN.md §11 satellites).

Checkpoint: atomic publish (temp + rename), per-array CRC32 manifest,
fallback to the last-good ``.prev`` generation on corruption.
MetricsWriter: bounded retry on transient OSError, drop-with-counter
after exhaustion — a flaky sink never kills the drain thread.
"""
import json
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs.sink import MetricsWriter
from repro.train.checkpoint import (CheckpointError, load_checkpoint,
                                    save_checkpoint)


def _tree(v=0.0):
    return {"a": jnp.full((4, 3), 1.5 + v), "m": None,
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


# ----------------------------------------------------------- checkpoint

def test_checkpoint_manifest_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, _tree(), step=3)
    with np.load(path) as data:
        assert "__manifest__" in data.files
        man = json.loads(bytes(data["__manifest__"]).decode())
    assert man["a"]["dtype"] == "float32" and man["a"]["shape"] == [4, 3]
    out, step = load_checkpoint(path, _tree())
    assert step == 3
    assert np.array_equal(np.asarray(out["a"]), np.asarray(_tree()["a"]))
    assert out["m"] is None
    assert not os.path.exists(path + ".tmp")


def test_checkpoint_rotates_prev_generation(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, _tree(0.0), step=1)
    save_checkpoint(path, _tree(9.0), step=2)
    assert os.path.exists(path + ".prev")
    out, step = load_checkpoint(path, _tree())
    assert step == 2 and float(out["a"][0, 0]) == pytest.approx(10.5)
    prev, pstep = load_checkpoint(path + ".prev", _tree())
    assert pstep == 1 and float(prev["a"][0, 0]) == pytest.approx(1.5)


def test_corrupt_checkpoint_falls_back_to_prev(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, _tree(0.0), step=1)
    save_checkpoint(path, _tree(9.0), step=2)
    with open(path, "r+b") as f:   # torn write: truncate the newest
        f.truncate(os.path.getsize(path) // 2)
    with pytest.warns(RuntimeWarning, match="falling back"):
        out, step = load_checkpoint(path, _tree())
    assert step == 1    # the last-good generation
    assert float(out["a"][0, 0]) == pytest.approx(1.5)


def test_checksum_mismatch_detected(tmp_path):
    """Silent bit-rot that keeps the zip structure valid is caught by
    the per-array CRC32 manifest, not just truncation."""
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, _tree(), step=1)
    man = json.dumps({"a": {"crc32": 1, "shape": [4, 3],
                            "dtype": "float32"}})
    flat = {"a": np.zeros((4, 3), np.float32),
            "__manifest__": np.frombuffer(man.encode(), np.uint8)}
    with open(path, "wb") as f:    # forged content, stale checksum
        np.savez(f, **flat)
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        load_checkpoint(path, {"a": jnp.zeros((4, 3))})


def test_both_generations_corrupt_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, _tree(), step=1)
    save_checkpoint(path, _tree(), step=2)
    for p in (path, path + ".prev"):
        with open(p, "wb") as f:
            f.write(b"not a zip")
    with pytest.raises(CheckpointError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            load_checkpoint(path, _tree())


def test_premanifest_checkpoint_still_loads(tmp_path):
    """Backward compat: archives written before the manifest existed
    (plain np.savez) load with the checksum pass skipped."""
    path = str(tmp_path / "old.npz")
    np.savez(path, **{"a": np.ones((2, 2)), "__step__": np.asarray(5)})
    out, step = load_checkpoint(path, {"a": jnp.zeros((2, 2))})
    assert step == 5 and np.asarray(out["a"]).sum() == 4.0


# ------------------------------------------------------------- sink

class _FlakyFile:
    """File wrapper failing the first ``n_fail`` write() calls."""

    def __init__(self, inner, n_fail):
        self._inner = inner
        self._left = n_fail
        self.attempts = 0

    def write(self, s):
        self.attempts += 1
        if self._left > 0:
            self._left -= 1
            raise OSError(28, "No space left on device")
        return self._inner.write(s)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_sink_retries_transient_oserror(tmp_path):
    path = str(tmp_path / "m.jsonl")
    w = MetricsWriter(path, write_retries=3, retry_backoff_s=0.001)
    flaky = _FlakyFile(w._file, n_fail=2)
    w._file = flaky
    w.write("step", step=0, loss=1.0)
    w.close()
    assert w.dropped == 0
    assert flaky.attempts == 3          # 2 failures + 1 success
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    assert recs and recs[-1]["loss"] == 1.0


def test_sink_drops_with_counter_after_retries(tmp_path):
    path = str(tmp_path / "m.jsonl")
    w = MetricsWriter(path, write_retries=2, retry_backoff_s=0.001)
    w._file = _FlakyFile(w._file, n_fail=10 ** 6)   # permanent failure
    w.write("step", step=0, loss=1.0)
    w.write("step", step=1, loss=2.0)
    with pytest.warns(RuntimeWarning, match="dropped 2 record"):
        w.close()                       # warns, never raises, on drops
    assert w.dropped == 2


def test_sink_drop_does_not_block_later_records(tmp_path):
    path = str(tmp_path / "m.jsonl")
    w = MetricsWriter(path, write_retries=1, retry_backoff_s=0.001)
    w._file = _FlakyFile(w._file, n_fail=2)   # kills exactly record 1
    w.write("step", step=0, loss=1.0)
    w.flush()
    w.write("step", step=1, loss=2.0)
    with pytest.warns(RuntimeWarning):
        w.close()
    assert w.dropped == 1
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    assert [r["step"] for r in recs] == [1]
