"""Partition rules + a real multi-device SPMD integration test.

The multi-device test runs in a subprocess so it can set
XLA_FLAGS=--xla_force_host_platform_device_count before jax initialises
(the main test process must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core.muon import ParamMeta
from repro.dist.sharding import (batch_pspec, ns_bucket_pspec, param_pspec,
                                 serve_pspecs)


class FakeMesh:
    """Shape-only stand-in (param_pspec only reads mesh.shape)."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def test_tp_shards_last_divisible_dim():
    m = ParamMeta("spectral", 1.0, 1)
    assert param_pspec(m, (40, 2048, 8192), MESH) == P(None, None, "model")
    # last dim not divisible -> second-to-last
    assert param_pspec(m, (40, 2048, 49155), MESH) == P(None, "model", None)
    # vectors replicated
    v = ParamMeta("sign", 1.0, 1, compressible=False)
    assert param_pspec(v, (40, 2048), MESH) == P(None, None)


def test_expert_parallel_dim():
    m = ParamMeta("spectral", 1.0, 2)
    # 256 experts over 16-way model axis
    assert param_pspec(m, (58, 256, 7168, 2048), MESH) == \
        P(None, "model", None, None)
    # 8 experts: not divisible by 16 -> fall through to TP on last dim
    assert param_pspec(m, (32, 8, 4096, 14336), MESH) == \
        P(None, None, None, "model")


def test_fsdp_adds_data_axis():
    m = ParamMeta("spectral", 1.0, 1)
    spec = param_pspec(m, (88, 12288, 28672), MESH, fsdp=True)
    assert "model" in spec and "data" in spec


def test_batch_pspec_single_vs_multipod():
    class S:  # ShapeDtypeStruct stand-in
        def __init__(self, shape):
            self.shape = shape

    b = {"tokens": S((16, 16, 4096))}
    assert batch_pspec(b, MESH, "train")["tokens"] == \
        P("data", None, None)
    b3 = {"tokens": S((2, 128, 4096))}
    assert batch_pspec(b3, MESH3, "train")["tokens"] == \
        P("pod", "data", None)
    d = {"token": S((128, 1))}
    assert batch_pspec(d, MESH, "decode")["token"] == P("data", None)


def test_serve_pspecs_shards_batch_and_seq():
    class S:
        def __init__(self, shape):
            self.shape = shape

    cache = {"k": S((40, 128, 32768, 8, 64))}
    spec = serve_pspecs(cache, 128, MESH)["k"]
    assert spec[1] == "data"       # batch dim
    assert "model" in spec         # sequence dim sharded


# ------------------------------------------------- ns_bucket_pspec rule

def test_ns_bucket_pspec_basics():
    # consistent TP (col) + batch divisible by the composed slow axes
    spec = ns_bucket_pspec(160, (2048, 2048), [(None, "model")] * 4, MESH3)
    assert spec == P(("pod", "data"), None, "model")
    # mixed up/down orientation: trailing dims stay unsharded
    spec = ns_bucket_pspec(80, (2048, 8192),
                           [(None, "model"), ("model", None)], MESH3)
    assert spec == P("data", None, None)
    # batch only divisible by pod
    spec = ns_bucket_pspec(40, (2048, 8192), [(None, "model")], MESH3)
    assert spec == P("pod", None, "model")
    # nothing divides, no TP: fully unsharded
    spec = ns_bucket_pspec(7, (48, 80), [(None, None)], MESH)
    assert spec == P(None, None, None)
    # members without TP don't veto the consistent ones
    spec = ns_bucket_pspec(32, (64, 2048),
                           [(None, "model"), (None, None)], MESH)
    assert spec == P("data", None, "model")
    # expert-parallel stacks (model on a stack dim, folded into the
    # batch dim): model composes into the batch sharding when the
    # trailing dims leave it free and the batch divides
    spec = ns_bucket_pspec(4096, (2048, 7168), [(None, None)], MESH3,
                           stack_model=True)
    assert spec == P(("pod", "data", "model"), None, None)
    # ... but never fights a trailing model assignment
    spec = ns_bucket_pspec(4096, (2048, 7168), [(None, "model")], MESH3,
                           stack_model=True)
    assert spec == P(("pod", "data"), None, "model")
    # and falls back through the slow-axis compositions when indivisible
    spec = ns_bucket_pspec(48, (2048, 7168), [(None, None)], MESH3,
                           stack_model=True)
    assert spec == P("data", None, None)


@given(data_n=st.integers(1, 8), model_n=st.integers(1, 8),
       pod_n=st.integers(1, 4), batch=st.integers(1, 96),
       m=st.sampled_from([8, 48, 64, 96]), n=st.sampled_from([64, 96, 256]),
       members=st.lists(st.sampled_from(
           [(None, "model"), ("model", None), (None, None),
            ("data", "model"), (None, "data")]), min_size=1, max_size=5))
@settings(max_examples=80, deadline=None)
def test_ns_bucket_pspec_property(data_n, model_n, pod_n, batch, m, n,
                                  members):
    """Mesh-shape x bucket-shape sweep: no mesh axis is ever assigned
    twice, the batch dim only shards when divisible (by the largest
    divisible slow-axis composition), and the trailing model dim only
    fires on a consistent member TP orientation with a divisible dim."""
    axes = {}
    if pod_n > 1:
        axes["pod"] = pod_n
    axes["data"] = data_n
    axes["model"] = model_n
    mesh = FakeMesh(**axes)
    if m > n:
        m, n = n, m
    spec = ns_bucket_pspec(batch, (m, n), members, mesh)
    assert len(spec) == 3
    flat = [a for e in spec if e is not None
            for a in ((e,) if isinstance(e, str) else tuple(e))]
    assert len(flat) == len(set(flat)), spec          # no double assignment
    lead, row, col = spec
    # batch dim: slow axes only, divisible, and maximal among candidates
    cands = [c for c in [("data",), ("pod",), ("pod", "data")]
             if all(a in mesh.axis_names and mesh.shape[a] > 1 for a in c)]
    div = [int(np.prod([mesh.shape[a] for a in c])) for c in cands
           if batch % int(np.prod([mesh.shape[a] for a in c])) == 0]
    if lead is None:
        assert not div
    else:
        lead_t = (lead,) if isinstance(lead, str) else tuple(lead)
        assert set(lead_t) <= {"pod", "data"}
        size = int(np.prod([mesh.shape[a] for a in lead_t]))
        assert batch % size == 0 and size == max(div)
    # trailing dims: model only, divisible, consistent orientation
    assert row in (None, "model") and col in (None, "model")
    pos = {(0 if r == "model" else 1)
           for r, c in members if "model" in (r, c)}
    if row == "model":
        assert pos == {0} and m % model_n == 0 and model_n > 1
    if col == "model":
        assert pos == {1} and n % model_n == 0 and model_n > 1
    if model_n > 1 and len(pos) == 1:
        p, d = next(iter(pos)), (m, n)[next(iter(pos))]
        if d % model_n == 0:
            assert (row, col)[p] == "model"


class S3:
    def __init__(self, shape):
        self.shape = shape


def test_serve_pspecs_rank_mismatch_raises():
    """cache/cache_alt leaves of different rank used to silently zip-
    truncate and could mis-identify the batch dim — now a clear error."""
    cache = {"k": S3((4, 8, 16))}
    alt = {"k": S3((4, 8, 16, 1))}
    with pytest.raises(ValueError, match="rank mismatch"):
        serve_pspecs(cache, 8, MESH, cache_alt=alt)


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import sys
sys.path.insert(0, "src")
from repro.configs import get_config
from repro.models.api import build_model, input_specs
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.hlo_cost import analyze
from repro.launch.hlo_analysis import attribute_u8_directions
from repro.analysis.rules import wire_budget_findings

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
cfg = get_config("granite-3-2b").reduced()
model = build_model(cfg)
# elastic/chaos arm (§11): same script, env-selected — the wire
# invariants below must hold under participation < 1 and injected drops
part = os.environ.get("REPRO_SPMD_PARTICIPATION", "full")
fspec = os.environ.get("REPRO_SPMD_FAULTS")
faults = None
if fspec:
    from repro.train.faults import parse_faults
    faults = parse_faults(fspec, 4)
# rejoin arm (§13): env-selected replay-ring depth; 0 = compiled out
resync = int(os.environ.get("REPRO_SPMD_RESYNC", "0"))
tr = Trainer(model, TrainerConfig(n_workers=4, beta=0.5,
                                  w2s="top10+natural", s2w="natural",
                                  use_pallas=False, remat=False,
                                  participation=part, faults=faults,
                                  resync=resync),
             mesh=mesh)
shape = ShapeSpec("t", "train", 32, 8)
data = SyntheticLM(cfg, shape, n_workers=4, seed=0)
batch = data.batch_at(0)
step = tr.jit_step(jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                               x.dtype),
                                batch))
state = tr.init(jax.random.key(0))
state = jax.device_put(state, tr.shardings(jax.tree.map(
    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))[0])
lowered = step.lower(state, batch, jnp.asarray(0.01, jnp.float32))
compiled = lowered.compile()
a = analyze(compiled.as_text())
plan = tr.layer_plan()
wire_dt = tr.opt.cfg.wire_dtype
# the resolved wire budget IS the expectation: the same object the §12
# wire-budget lint rule checks, so this test and the lint CLI share one
# definition of "correct wire population"
budget = tr.wire_budget()
stage_bytes = list(budget.w2s_sizes)
s2w_stage_bytes = list(budget.s2w_sizes)
findings = wire_budget_findings(
    [p for p in a["coll_pairs"] if p["u8"]], budget, "spmd")
# the wire collectives themselves are the u8 all-gathers; the SPMD
# partitioner additionally assembles the TP-sharded s2w pack buffer via
# masked dynamic-update-slice + u8 all-reduce (compressed-domain repack,
# see the test docstring) — keep the two populations separate
gathers = [p for p in a["coll_pairs"] if p["u8"]
           and p["kind"] == "all-gather"]
residual = [p for p in a["coll_pairs"] if p["u8"]
            and p["kind"] != "all-gather"]
split = attribute_u8_directions(gathers, stage_bytes, s2w_stage_bytes)
# run two real steps on 8 host devices (plus a third on the resync arm,
# so a drop -> rejoin -> replay cycle completes inside the run)
state, aux1 = step(state, batch, 0.01)
state, aux2 = step(state, data.batch_at(1), 0.01)
auxes = [aux1, aux2]
if resync:
    state, aux3 = step(state, data.batch_at(2), 0.01)
    auxes.append(aux3)
resync_rec = None
if resync:
    resync_rec = {
        "replayed": [int(np.asarray(a["resync_replayed"])) for a in auxes],
        "full": [int(np.asarray(a["resync_full"])) for a in auxes],
        "lag_max": [int(np.asarray(a["version_lag_max"])) for a in auxes],
    }
    # bit-equality of every worker's W estimate against the server's,
    # leaf by leaf, straight off the sharded device arrays
    eq = True
    flat_w = jax.tree.leaves(state["w"])
    flat_ww = jax.tree.leaves(state["w_w"])
    for w, ww in zip(flat_w, flat_ww):
        for j in range(4):
            eq = eq and bool(np.array_equal(np.asarray(ww[j]),
                                            np.asarray(w)))
    resync_rec["w_w_equals_w"] = eq
print(json.dumps({
    "loss1": float(aux1["loss"]), "loss2": float(aux2["loss"]),
    "coll_bytes": a["coll_bytes"], "coll_by_kind": a["coll_by_kind"],
    "u8_bytes": a["u8_coll_bytes"], "u8_count": a["u8_coll_count"],
    "analytic_bytes": plan.w2s_bytes_per_worker(wire_dt),
    "s2w_analytic_bytes": plan.s2w_bytes_per_round(wire_dt),
    "wire_bytes": budget.w2s_nbytes,
    "s2w_wire_bytes": budget.s2w_nbytes,
    "n_stages": budget.n_stages,
    "stage_bytes": stage_bytes,
    "s2w_stage_bytes": s2w_stage_bytes,
    "split": split,
    "wire_findings": [f.message for f in findings],
    "buffer_bytes": plan.wire_layout(wire_dt).total_nbytes,
    "s2w_buffer_bytes": plan.wire_layout(wire_dt,
                                         direction="s2w").total_nbytes,
    "u8_gather_bytes": sorted(int(p["bytes"]) for p in gathers),
    "u8_residual_bytes": sum(int(p["bytes"]) for p in residual),
    "u8_residual_kinds": sorted({p["kind"] for p in residual}),
    "flops": a["flops"],
    "n_participants": [int(a.get("n_participants", -1))
                       for a in (aux1, aux2)],
    "skipped": [bool(np.asarray(a.get("skipped", False)))
                for a in (aux1, aux2)],
    "resync": resync_rec,
}))
"""


def _run_spmd_script(extra_env: dict | None = None) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.update(extra_env or {})
    out = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
        timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _assert_wire_invariants(rec: dict) -> None:
    """The §8/§9 staged-wire SPMD invariants — shared by the full and
    the elastic arms (the masked fold must not change a single byte).

    The invariant itself now lives in ONE place:
    ``repro.analysis.rules.wire_budget_findings`` checks the u8
    collective population against the trainer's resolved ``WireBudget``
    (exactly 2K byte-equal gathers, attribution exact, residual u8
    all-reduce bounded by one s2w buffer) — the same function the §12
    lint CLI runs over the whole config matrix, so this test and the
    linter cannot drift apart. The SPMD script ran it in-process; an
    empty finding list is the assertion. The remaining checks pin what
    the rule deliberately doesn't own: the budget really resolved to a
    staged multi-stage pipeline, byte totals match the single-buffer
    WireLayout accounts, and the module-wide u8 byte total decomposes
    exactly into wire + repack."""
    assert rec["coll_bytes"] > 0
    assert rec["wire_findings"] == [], rec
    # wire_stages="auto" really staged both buffers (K > 1), and the
    # per-stage budget sums reproduce the monolithic buffer accounts
    assert rec["n_stages"] > 1, rec
    assert len(rec["u8_gather_bytes"]) == 2 * rec["n_stages"], rec
    assert sum(rec["stage_bytes"]) == rec["buffer_bytes"], rec
    assert sum(rec["s2w_stage_bytes"]) == rec["s2w_buffer_bytes"], rec
    assert rec["u8_gather_bytes"] == \
        sorted(rec["stage_bytes"] + rec["s2w_stage_bytes"]), rec
    assert rec["split"]["w2s"] == {"bytes": rec["wire_bytes"],
                                   "count": rec["n_stages"]}, rec
    assert rec["split"]["s2w"] == {"bytes": rec["s2w_wire_bytes"],
                                   "count": rec["n_stages"]}, rec
    # module-wide u8 bytes decompose exactly into wire + repack
    assert rec["u8_residual_bytes"] <= rec["s2w_wire_bytes"], rec
    assert rec["u8_bytes"] == rec["wire_bytes"] + rec["s2w_wire_bytes"] \
        + rec["u8_residual_bytes"], rec


@pytest.mark.slow
def test_spmd_train_step_runs_on_8_devices():
    """Real SPMD execution: the jitted EF21-Muon step runs on an 8-device
    host mesh, produces finite losses, and BOTH wire directions obey the
    staged wire invariant (DESIGN.md §8, §9): exactly 2K uint8
    all-gathers — one payload gather (w2s) plus one model-update
    broadcast (s2w) per pipeline stage — whose measured HLO bytes sum
    byte-for-byte to the two repro.wire offset-table accounts, each
    collective moving exactly its stage sub-buffer, and the two-way
    total agreeing with the analytic Table-2 account (within 1.15x; the
    wire is *below* it because narrow index encoding beats the paper's
    4-byte-index convention).

    One SPMD artifact is tolerated and pinned down separately: the s2w
    pack inputs (W, X) are TP-sharded over the model axis, and
    flattening a model-sharded leaf into the byte dim has no
    representable sharding, so the partitioner assembles the replicated
    buffer via masked dynamic-update-slice + u8 *all-reduce*. That is
    compressed-domain repack traffic (the real system pays it too, on
    the fast intra-server links, to assemble the message from TP
    shards), NOT the broadcast — it must stay all-reduce-kind and
    bounded by one s2w buffer. The w2s leg avoids it only because TopK
    compression already gathers in f32 upstream."""
    rec = _run_spmd_script()
    assert np.isfinite(rec["loss1"]) and np.isfinite(rec["loss2"])
    _assert_wire_invariants(rec)
    # and each direction (plus the two-way total) agrees with the
    # analytic Table-2 account (<= 1.15x)
    assert rec["wire_bytes"] <= 1.15 * rec["analytic_bytes"], rec
    assert rec["s2w_wire_bytes"] <= 1.15 * rec["s2w_analytic_bytes"], rec
    two_way_analytic = rec["analytic_bytes"] + rec["s2w_analytic_bytes"]
    two_way = rec["wire_bytes"] + rec["s2w_wire_bytes"]
    assert two_way <= 1.15 * two_way_analytic, rec
    assert two_way >= 0.25 * two_way_analytic, rec


@pytest.mark.slow
def test_spmd_elastic_worker_dropped_keeps_wire_invariants():
    """§11 acceptance: the same 8-device SPMD step under elastic
    participation (round_robin(3): one worker out per step) PLUS an
    injected drop fault keeps every §8/§9 wire invariant — exactly 2K
    static-shape u8 all-gathers, byte-for-byte equal to both staged
    layouts — because absence is applied at fold time, never to the
    collectives. Losses stay finite and the dynamic participant count
    shows the mask actually bit (scheduled 3, minus the dropped worker
    when it overlaps the window)."""
    rec = _run_spmd_script({
        "REPRO_SPMD_PARTICIPATION": "round_robin(3)",
        "REPRO_SPMD_FAULTS": "drop:w=1:steps=0-2"})
    assert np.isfinite(rec["loss1"]) and np.isfinite(rec["loss2"])
    _assert_wire_invariants(rec)
    # participation < 1 was really in effect: round_robin(3) keeps 3 of
    # 4 workers; the drop fault removes worker 1 when it is scheduled
    assert all(0 < n < 4 for n in rec["n_participants"]), rec
    assert rec["skipped"] == [False, False], rec


@pytest.mark.slow
def test_spmd_resync_rejoin_keeps_wire_invariants():
    """§13 acceptance: the same 8-device SPMD step with the rejoin
    subsystem compiled in (R=4 replay ring, per-worker W estimates)
    under a drop -> rejoin -> replay cycle — worker 1 misses the s2w
    broadcasts of steps 0 and 1, rejoins at step 2 with lag 2 <= R and
    catches up by replaying ring slots. The §8/§9 wire invariants must
    hold byte-for-byte on this arm too: replay adds NO collectives (the
    ring is replicated, decompression is local), so the u8 population
    is exactly the same 2K staged gathers. The replayed counter proves
    the replay really fired, and every worker's W estimate leaves the
    run bit-equal to the server's — the pinned resync invariant, on the
    production-sharded program."""
    rec = _run_spmd_script({
        "REPRO_SPMD_RESYNC": "4",
        "REPRO_SPMD_FAULTS": "drop:w=1:steps=0-2"})
    assert np.isfinite(rec["loss1"]) and np.isfinite(rec["loss2"])
    _assert_wire_invariants(rec)
    rs = rec["resync"]
    assert rs is not None, rec
    # steps 0,1: worker 1 absent (lag grows); step 2: rejoin via replay
    assert rs["lag_max"][:2] == [1, 2], rec
    assert rs["lag_max"][2] == 0, rec
    assert rs["replayed"][2] >= 1, rec
    assert sum(rs["full"]) == 0, rec
    assert rs["w_w_equals_w"] is True, rec
