"""Observability layer (DESIGN.md §10): MetricSet pytree semantics, the
norm helpers, host span timing, the schema-versioned sink (round-trip +
validation over the committed history), and the two guard invariants —
metrics-on is value-bit-equal to metrics-off, and the everything-off arm
lowers with zero span metadata in the compiled HLO. The slow test
captures a real profiler trace of one eager staged step and asserts
every phase and wire-stage span name appears in it."""
import glob
import gzip
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.layerwise import LayerPlan
from repro.obs.metrics import (MetricSet, leaf_names, orth_residual,
                               rel_error, worker_mean_norm)
from repro.obs.sink import (SCHEMA, MetricsWriter, SchemaError, config_hash,
                            run_manifest, validate_bench_file,
                            validate_jsonl, validate_record,
                            write_bench_artifact)
from repro.obs.trace import (PHASE_SPANS, SpanRecorder, phase_span, span,
                             span_summary, wire_stage_span)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _tree(key):
    """The test_pipeline fixture: eager (sign) leaves + three NS buckets."""
    ks = jax.random.split(key, 7)
    params = {
        "wq": jax.random.normal(ks[0], (48, 32)),
        "wk": jax.random.normal(ks[1], (48, 32)),
        "w_in": jax.random.normal(ks[2], (32, 80)),
        "w_out": jax.random.normal(ks[3], (80, 32)),
        "blocks": jax.random.normal(ks[4], (3, 48, 32)),
        "tiny": jax.random.normal(ks[5], (16, 16)),
        "bias": jax.random.normal(ks[6], (32,)),
    }
    metas = {
        "wq": ParamMeta("spectral", 1.0, 0),
        "wk": ParamMeta("spectral", 1.0, 0),
        "w_in": ParamMeta("spectral", 1.5, 0),
        "w_out": ParamMeta("spectral", 1.0, 0),
        "blocks": ParamMeta("spectral", 2.0, 1),
        "tiny": ParamMeta("spectral", 1.0, 0),
        "bias": ParamMeta("sign", 1.0, 0, compressible=False),
    }
    return params, metas


def _quadratic_grad(params, batch):
    loss = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - batch))
               for p in jax.tree.leaves(params))
    grads = jax.tree.map(
        lambda p: 2.0 * (p.astype(jnp.float32) - batch), params)
    return loss, grads


def _opt(**cfg_kw):
    return EF21Muon(EF21MuonConfig(n_workers=2, beta=0.5,
                                   w2s="top10+natural", s2w="natural",
                                   use_pallas=False, **cfg_kw))


def _run_steps(params, metas, key, n=3, **cfg_kw):
    opt = _opt(**cfg_kw)
    state = opt.init(key, params, metas)
    fn = opt.make_step(metas, reshard_payloads=lambda t: t)
    step = jax.jit(lambda s, b, t, f=fn: f(s, _quadratic_grad, b, t))
    for _ in range(n):
        state, aux = step(state, jnp.ones((2, 1)) * 0.1, 0.01)
    assert np.isfinite(float(aux["loss"]))
    return state, aux


# ----------------------------------------------------------- MetricSet

def test_metricset_pytree_roundtrip():
    ms = MetricSet()
    ms.add("ef/err_norm/a", 1.5)
    ms.add("wire/bytes_w2s", 42.0)
    assert ms.names() == ("ef/err_norm/a", "wire/bytes_w2s")
    assert len(ms) == 2 and "ef/err_norm/a" in ms
    doubled = jax.tree.map(lambda v: 2 * v, ms)
    assert isinstance(doubled, MetricSet)
    assert doubled.names() == ms.names()           # names ride the treedef
    assert float(doubled["ef/err_norm/a"]) == 3.0
    # survives a jit boundary as an output pytree
    out = jax.jit(lambda m: jax.tree.map(lambda v: v + 1, m))(ms)
    assert out.names() == ms.names()
    hf = out.host_floats()
    assert hf == {"ef/err_norm/a": 2.5, "wire/bytes_w2s": 43.0}
    assert all(isinstance(v, float) for v in hf.values())


def test_metricset_rejects_bad_and_duplicate_names():
    ms = MetricSet()
    ms.add("ok/name", 1.0)
    with pytest.raises(ValueError):
        ms.add("ok/name", 2.0)                     # duplicate
    for bad in ("", "a b", "a//b", "/lead", "trail/", "a\nb"):
        with pytest.raises(ValueError):
            ms.add(bad, 0.0)


def test_norm_helpers(key):
    x = jax.random.normal(key, (2, 5, 7))
    got = worker_mean_norm(x)
    want = np.mean([np.linalg.norm(np.asarray(x[j])) for j in range(2)])
    np.testing.assert_allclose(float(got), want, rtol=1e-6)
    # lead=0: one global F-norm
    np.testing.assert_allclose(float(worker_mean_norm(x, lead=0)),
                               np.linalg.norm(np.asarray(x).ravel()),
                               rtol=1e-6)
    # rel_error: ratio per worker, and 0 (not nan/inf) on a zero target
    r = rel_error(x, 2.0 * x)
    np.testing.assert_allclose(float(r), 0.5, rtol=1e-6)
    assert float(rel_error(x, jnp.zeros_like(x))) == 0.0


def test_orth_residual():
    # orthogonal rows -> residual 0; doubling them -> ||4I - I||_F = 3*sqrt(k)
    q = jnp.eye(4)[None, :3, :]                     # [1, 3, 4], QQ^T = I_3
    np.testing.assert_allclose(float(orth_residual(q)), 0.0, atol=1e-6)
    np.testing.assert_allclose(float(orth_residual(2.0 * q)),
                               3.0 * np.sqrt(3.0), rtol=1e-6)
    # tall input uses the column gram (smaller side)
    tall = jnp.eye(4)[None, :, :2]                  # [1, 4, 2], D^T D = I_2
    np.testing.assert_allclose(float(orth_residual(tall)), 0.0, atol=1e-6)


def test_leaf_names():
    tree = {"a": {"b": 1.0}, "c": [2.0, 3.0], "d w": 4.0}
    assert leaf_names(tree) == ("a/b", "c/0", "c/1", "d-w")
    assert leaf_names({}) == ()
    # flatten order matches treedef order (the metric <-> leaf contract)
    leaves, _ = jax.tree.flatten(tree)
    assert len(leaves) == len(leaf_names(tree))


# ---------------------------------------------------------- trace spans

def test_wire_stage_span_names():
    assert wire_stage_span("w2s", 0) == "wire/w2s/stage0"
    assert wire_stage_span("s2w", 3) == "wire/s2w/stage3"
    with pytest.raises(ValueError):
        wire_stage_span("up", 0)


def test_span_recorder_and_timer():
    rec = SpanRecorder()
    with span("t/outer", recorder=rec):
        time.sleep(0.01)
        with span("t/inner", recorder=rec):
            pass
    with span("t/inner", recorder=rec):
        pass
    rows = span_summary(rec)
    by_name = {r["name"]: r for r in rows}
    # rows in completion order: nested spans record on exit
    assert [r["name"] for r in rows] == ["t/inner", "t/outer"]
    assert by_name["t/inner"]["count"] == 2
    assert by_name["t/outer"]["total_s"] >= 0.01
    assert by_name["t/outer"]["max_s"] <= by_name["t/outer"]["total_s"] + 1e-9
    rec.clear()
    assert span_summary(rec) == []
    # span rows are valid sink records as-is
    for r in rows:
        validate_record({"schema": SCHEMA, "kind": "span", **r})


def test_phase_span_is_reentrant_under_trace():
    # graph arm inside a trace: named_scope must accept the names
    @jax.jit
    def f(x):
        with phase_span(PHASE_SPANS[0], True):
            with phase_span(wire_stage_span("w2s", 1), True):
                return x * 2
    assert float(f(jnp.float32(3.0))) == 6.0


# ------------------------------------------------- step guard invariants

def test_metrics_on_bit_equal_and_content(key):
    """The §10 acceptance pair: metrics-on produces the identical state
    bits, and aux["metrics"] carries the full taxonomy with sane values."""
    params, metas = _tree(key)
    base, _ = _run_steps(params, metas, key, wire_stages="auto")
    got, aux = _run_steps(params, metas, key, wire_stages="auto",
                          metrics=True)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), got, base)
    assert all(jax.tree.leaves(same)), same

    ms = aux["metrics"]
    assert isinstance(ms, MetricSet)
    vals = ms.host_floats()
    assert all(np.isfinite(v) for v in vals.values()), vals
    for leaf in leaf_names(params):
        assert f"ef/err_norm/{leaf}" in ms
        assert f"ef/rel_err/{leaf}" in ms
        assert f"ef/momentum_norm/{leaf}" in ms
        assert f"efp/err_norm/{leaf}" in ms        # s2w="natural" engaged
    # the incompressible identity leaf has zero EF error by construction
    assert vals["ef/err_norm/bias"] == 0.0
    assert vals["ef/rel_err/bias"] == 0.0
    # static wire accounting matches the layouts exactly
    plan = LayerPlan.build(params, metas, w2s="top10+natural",
                           s2w="natural")
    dt = _opt().cfg.wire_dtype
    assert vals["wire/bytes_w2s"] == plan.wire_layout(dt).total_nbytes
    assert vals["wire/bytes_s2w"] == \
        plan.wire_layout(dt, direction="s2w").total_nbytes
    n_stages = plan.stage_plan().n_stages
    assert vals["wire/n_stages"] == n_stages
    # one NS residual per bucket, all strictly positive (finite chains)
    res = [n for n in ms.names() if n.startswith("ns/orth_residual/")]
    assert len(res) == len(plan.ns_buckets())
    assert all(vals[n] > 0 for n in res)
    # step rows built from these metrics validate against the sink schema
    validate_record({"schema": SCHEMA, "kind": "step", "step": 3,
                     "loss": 1.0, "metrics": vals})


def test_trace_spans_bit_equal(key):
    """trace_spans=True changes op metadata only — never the values."""
    params, metas = _tree(key)
    for ws in ("auto", 1):
        base, _ = _run_steps(params, metas, key, wire_stages=ws)
        got, _ = _run_steps(params, metas, key, wire_stages=ws,
                            trace_spans=True)
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), got, base)
        assert all(jax.tree.leaves(same)), (ws, same)


def _lowered_debug_text(params, metas, key, **cfg_kw):
    """Lowered module printed WITH debug locations — where named_scope
    lands before any fusion can merge ops away (compiled-HLO op_name
    metadata drops scopes whose ops fuse, e.g. the tiny eager stage)."""
    import io
    opt = _opt(wire_stages="auto", **cfg_kw)
    state = opt.init(key, params, metas)
    fn = opt.make_step(metas, reshard_payloads=lambda t: t)
    step = jax.jit(lambda s, b, t, f=fn: f(s, _quadratic_grad, b, t))
    low = step.lower(state, jnp.ones((2, 1)) * 0.1,
                     jnp.asarray(0.01, jnp.float32))
    buf = io.StringIO()
    low.compiler_ir().operation.print(file=buf, enable_debug_info=True)
    return buf.getvalue()


def test_span_metadata_gated_by_trace_spans(key):
    """The HLO-identity boundary: with everything off, no span name
    reaches the lowered module (same guard style as the §8
    wire_stages=1 arm — the default build must not know obs exists);
    with trace_spans=True every phase + wire-stage name is op metadata."""
    params, metas = _tree(key)
    off = _lowered_debug_text(params, metas, key)
    for name in PHASE_SPANS:
        assert name not in off
    assert "wire/w2s/stage" not in off and "wire/s2w/stage" not in off

    on = _lowered_debug_text(params, metas, key, trace_spans=True)
    n_stages = LayerPlan.build(params, metas, w2s="top10+natural",
                               s2w="natural").stage_plan().n_stages
    for name in PHASE_SPANS:
        assert name in on, name
    for k in range(n_stages):
        assert wire_stage_span("w2s", k) in on
        assert wire_stage_span("s2w", k) in on


# ----------------------------------------------------------------- sink

def test_metrics_writer_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    manifest = run_manifest(config={"beta": 0.5}, mesh=None,
                            extra={"arch": "t"})
    with MetricsWriter(path, manifest=manifest, flush_every=2) as w:
        w.write("step", step=0, loss=jnp.float32(3.25),
                metrics={"ef/err_norm/a": jnp.float32(0.5)})
        w.write("step", step=1, loss=1.0)
        w.write("span", name="plan/build", count=1, total_s=0.01)
        w.write("summary", spans=[{"name": "plan/build", "count": 1,
                                   "total_s": 0.01}])
    counts = validate_jsonl(path)
    assert counts == {"manifest": 1, "step": 2, "span": 1, "summary": 1}
    recs = [json.loads(line) for line in open(path)]
    assert all(r["schema"] == SCHEMA for r in recs)
    assert recs[0]["kind"] == "manifest"
    assert recs[0]["config_hash"] == config_hash({"beta": 0.5})
    assert recs[0]["arch"] == "t" and "argv" in recs[0]
    # jax scalars landed as plain JSON numbers
    assert recs[1]["loss"] == 3.25
    assert recs[1]["metrics"]["ef/err_norm/a"] == 0.5


def test_metrics_writer_append_resume(tmp_path):
    path = str(tmp_path / "d.jsonl")
    row = dict(arch="a", shape="s", mesh="single", tag="t", status="ok")
    with MetricsWriter(path, append=True) as w:
        w.write_record({"kind": "dryrun", **row})
    with MetricsWriter(path, append=True) as w:     # resume: no rewrite
        w.write_record({"kind": "dryrun", **row, "tag": "t2"})
    assert validate_jsonl(path) == {"dryrun": 2}
    tags = [json.loads(line)["tag"] for line in open(path)]
    assert tags == ["t", "t2"]


def test_writer_rejects_bad_records(tmp_path):
    with MetricsWriter(str(tmp_path / "x.jsonl")) as w:
        with pytest.raises(SchemaError):
            w.write("step", step=0)                 # missing loss
        with pytest.raises(SchemaError):
            w.write("nope", a=1)                    # unknown kind
        with pytest.raises(SchemaError):
            w.write("step", step=0, loss=1.0,
                    metrics={"a": "not-a-number"})
    assert validate_jsonl(str(tmp_path / "x.jsonl")) == {}


def test_validate_record_legacy_and_envelope():
    # legacy bench row: "kind" is a domain field, not the discriminator
    assert validate_record({"bench": "ns", "kind": "dispatch"}) == "bench"
    # legacy dryrun row (the committed pre-v1 shape)
    assert validate_record({"arch": "a", "shape": "s", "mesh": "m",
                            "tag": "t", "status": "ok"}) == "dryrun"
    # enveloped records enforce the discriminator + schema version
    with pytest.raises(SchemaError):
        validate_record({"schema": SCHEMA, "kind": "step", "bench": "x"},
                        kind="bench")               # kind mismatch
    with pytest.raises(SchemaError):
        validate_record({"schema": "repro.metrics/v0", "kind": "bench",
                         "bench": "x"})
    with pytest.raises(SchemaError):
        validate_record({"mystery": 1})             # uninferrable
    with pytest.raises(SchemaError):
        validate_record({"schema": SCHEMA, "kind": "step", "step": "0",
                         "loss": 1.0})              # step must be int


def test_validate_committed_history():
    """The committed sink files all pass the v1 validator: the dry-run
    log (legacy + new rows) and every BENCH_*.json artifact."""
    counts = validate_jsonl(os.path.join(REPO, "results/dryrun.jsonl"))
    assert counts.get("dryrun", 0) > 0, counts
    benches = glob.glob(os.path.join(REPO, "BENCH_*.json"))
    assert benches
    for p in benches:
        assert validate_bench_file(p) > 0, p


def test_write_bench_artifact_validates(tmp_path):
    path = str(tmp_path / "BENCH_t.json")
    rows = [{"bench": "t", "value": 1}, {"bench": "t", "value": 2}]
    write_bench_artifact(path, "t", rows, fast=True)
    assert validate_bench_file(path) == 2
    doc = json.load(open(path))
    assert doc["bench"] == "t" and doc["fast"] is True
    with pytest.raises(SchemaError):
        write_bench_artifact(path, "t", [{"value": 3}])   # no bench key


# ------------------------------------------------------ profiler capture

@pytest.mark.slow
def test_profiler_capture_contains_all_spans(key, tmp_path):
    """The §10 acceptance capture: one staged step run eagerly (host
    TraceAnnotations only time real work outside jit) under
    jax.profiler.trace must record a span for all five phases and every
    wire-stage collective in both directions."""
    params, metas = _tree(key)
    opt = _opt(wire_stages="auto", metrics=True, trace_spans=True)
    state = opt.init(key, params, metas)
    fn = opt.make_step(metas, reshard_payloads=lambda t: t)
    with jax.profiler.trace(str(tmp_path), create_perfetto_trace=True):
        state, aux = fn(state, _quadratic_grad,   # eager: no jit wrapper
                        jnp.ones((2, 1)) * 0.1, 0.01)
        jax.block_until_ready(state)

    blob = b""
    for p in glob.glob(str(tmp_path / "**" / "*"), recursive=True):
        if not os.path.isfile(p):
            continue
        with open(p, "rb") as f:
            raw = f.read()
        if p.endswith(".gz"):
            raw = gzip.decompress(raw)
        blob += raw
    assert blob, "profiler produced no trace files"

    n_stages = LayerPlan.build(params, metas, w2s="top10+natural",
                               s2w="natural").stage_plan().n_stages
    assert n_stages > 1
    expected = list(PHASE_SPANS)
    for k in range(n_stages):
        expected.append(wire_stage_span("w2s", k))
        expected.append(wire_stage_span("s2w", k))
    missing = [n for n in expected if n.encode() not in blob]
    assert not missing, f"spans absent from the trace: {missing}"
    assert len(aux["metrics"]) > 0                  # metrics rode along
