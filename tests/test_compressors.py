"""Contractive-compressor properties (paper Def. 1, §D)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
# property tests skip individually when hypothesis is absent; the
# plain oracle tests in this file still run (see _hypothesis_compat)
from _hypothesis_compat import given, settings, st

from repro.core import compressors as C
from repro.core.norms import norm


def _contract_ratio(comp, key, x, kind="frobenius", trials=4):
    """E||C(x)-x||^2 / ||x||^2 (should be <= 1 - alpha)."""
    state = comp.init(key, x.shape, x.dtype)
    tot = 0.0
    for i in range(trials):
        payload, state = comp.compress(state, x)
        xh = comp.decompress(payload, x.shape, jnp.float32)
        tot += float(norm(xh - x.astype(jnp.float32), kind)) ** 2
    return tot / trials / float(norm(x, kind)) ** 2


@pytest.mark.parametrize("name", sorted(C.REGISTRY))
def test_registry_roundtrip_shapes(name, key):
    comp = C.get_compressor(name)
    shape = (24, 16)
    x = jax.random.normal(key, shape, jnp.float32)
    state = comp.init(key, shape, jnp.dtype(jnp.bfloat16))
    payload, state = comp.compress(state, x.astype(jnp.bfloat16))
    xh = comp.decompress(payload, shape, jnp.float32)
    assert xh.shape == shape and xh.dtype == jnp.float32
    assert comp.payload_bytes(shape, jnp.bfloat16) > 0


def test_topk_contractive_euclidean(key):
    x = jax.random.normal(key, (32, 16))
    for frac in (0.05, 0.1, 0.2, 0.5):
        r = _contract_ratio(C.TopK(frac), key, x)
        assert r <= 1.0 - frac * 0.5  # top-k beats random-k = 1 - frac


def test_topk_exact_on_sparse(key):
    x = jnp.zeros((10, 10)).at[3, 4].set(5.0).at[7, 1].set(-2.0)
    comp = C.TopK(0.02)  # k = 2
    payload, _ = comp.compress(comp.init(key, x.shape, x.dtype), x)
    xh = comp.decompress(payload, x.shape, jnp.float32)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(x))


def test_topksvd_alpha_matches_formula(key):
    """alpha = 1 - (sum_{i>K} s_i^p / sum s_i^p)^{2/p} for Schatten-p."""
    x = jax.random.normal(key, (12, 9))
    s = jnp.linalg.svd(x, compute_uv=False)
    for K in (1, 3, 5):
        comp = C.TopKSVD(rank=K)
        payload, _ = comp.compress({}, x)
        xh = comp.decompress(payload, x.shape, jnp.float32)
        # spectral: residual = s_{K+1}
        np.testing.assert_allclose(float(norm(xh - x, "spectral")),
                                   float(s[K]), rtol=1e-4)
        # nuclear: residual = sum_{i>K} s_i
        np.testing.assert_allclose(float(norm(xh - x, "nuclear")),
                                   float(jnp.sum(s[K:])), rtol=1e-4)
        # frobenius
        np.testing.assert_allclose(
            float(norm(xh - x, "frobenius")),
            float(jnp.sqrt(jnp.sum(s[K:] ** 2))), rtol=1e-4)


def test_column_topk_contractive_mixed_norm(key):
    x = jax.random.normal(key, (16, 20))
    comp = C.ColumnTopK(0.25)
    payload, _ = comp.compress({}, x)
    xh = comp.decompress(payload, x.shape, jnp.float32)
    # kept columns exact, residual only on dropped ones
    kept = np.asarray(payload["indices"])
    np.testing.assert_allclose(np.asarray(xh)[:, kept],
                               np.asarray(x)[:, kept], rtol=1e-6)
    r = _contract_ratio(comp, key, x, kind="col_l2_dual")
    assert r < 1.0


def test_natural_relative_error_bound(key):
    """|C(x) - x| <= |x| / 3 elementwise => alpha >= 8/9 (§D / Horvath)."""
    x = jax.random.normal(key, (64, 64)) * jnp.exp(
        jax.random.normal(jax.random.fold_in(key, 1), (64, 64)) * 3)
    comp = C.Natural()
    payload, _ = comp.compress({}, x.astype(jnp.bfloat16))
    xh = np.asarray(comp.decompress(payload, x.shape, jnp.float32))
    xb = np.asarray(x.astype(jnp.bfloat16), np.float32)
    rel = np.abs(xh - xb) / np.maximum(np.abs(xb), 1e-30)
    assert rel.max() <= 1 / 3 + 1e-2
    assert _contract_ratio(comp, key, x.astype(jnp.bfloat16)) <= 1 / 9 + 0.01


def test_natural_preserves_powers_of_two(key):
    x = jnp.array([1.0, 2.0, -4.0, 0.5, -0.25, 0.0, 1024.0])
    comp = C.Natural()
    payload, _ = comp.compress({}, x.astype(jnp.bfloat16))
    xh = comp.decompress(payload, x.shape, jnp.float32)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(x))


def test_dropout_damping_alpha(key):
    x = jax.random.normal(key, (16, 16))
    # damping: deterministic ratio (1-gamma)^2
    for g in (0.5, 1.0, 1.5):
        r = _contract_ratio(C.Damping(g), key, x, trials=1)
        np.testing.assert_allclose(r, (1 - g) ** 2, rtol=1e-5, atol=1e-7)
    # dropout: E ratio = 1 - p
    comp = C.RandomDropout(0.7)
    r = _contract_ratio(comp, key, x, trials=64)
    assert abs(r - 0.3) < 0.15


def test_rankk_approximately_contractive(key):
    """PowerSGD-style RankK with NS orthonormalisation + warm start:
    contractive in expectation after warm-up (Remark 11)."""
    comp = C.RankK(fraction=0.25)
    x = jax.random.normal(key, (32, 24))
    state = comp.init(key, x.shape, jnp.float32)
    # warm-start: iterate on the same matrix; ratio should drop well < 1
    for _ in range(3):
        payload, state = comp.compress(state, x)
    xh = comp.decompress(payload, x.shape, jnp.float32)
    s = jnp.linalg.svd(x, compute_uv=False)
    best = float(jnp.sum(s[comp.rank_for(x.shape):] ** 2) / jnp.sum(s ** 2))
    ratio = float(norm(xh - x, "frobenius") ** 2 / norm(x, "frobenius") ** 2)
    assert ratio < 1.0
    assert ratio < 2.5 * best + 0.2  # near the optimal rank-K residual


def test_with_natural_combo_bytes(key):
    """TopK+Natural / RankK+Natural payloads: float planes shrink to
    9 bits/value; indices stay int32 (paper Table 2 accounting)."""
    shape = (64, 48)
    top = C.WithNatural(C.TopK(0.1))
    k = top.inner.k_for(shape)
    assert top.payload_bytes(shape, jnp.bfloat16) == k * 4 + k + (k + 7) // 8
    rk = C.WithNatural(C.RankK(fraction=0.1))
    r = rk.inner.rank_for(shape)
    nn = (64 + 48) * r
    assert rk.payload_bytes(shape, jnp.bfloat16) == nn + (nn + 7) // 8
    # roundtrip
    x = jax.random.normal(key, shape)
    st_ = rk.init(key, shape, jnp.dtype(jnp.bfloat16))
    payload, st_ = rk.compress(st_, x.astype(jnp.bfloat16))
    xh = rk.decompress(payload, shape, jnp.float32)
    assert xh.shape == shape
    assert not bool(jnp.any(jnp.isnan(xh)))


@given(frac=st.sampled_from([0.05, 0.1, 0.25]),
       m=st.integers(4, 40), n=st.integers(4, 40),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_topk_contraction_property(frac, m, n, seed):
    """Hypothesis: Def. 1 holds for TopK with alpha = fraction for any
    shape/seed (classical result: top-k >= random-k)."""
    x = jax.random.normal(jax.random.key(seed), (m, n))
    comp = C.TopK(frac)
    payload, _ = comp.compress({}, x)
    xh = comp.decompress(payload, (m, n), jnp.float32)
    lhs = float(jnp.sum((xh - x) ** 2))
    rhs = (1 - comp.k_for((m, n)) / (m * n)) * float(jnp.sum(x ** 2))
    assert lhs <= rhs + 1e-5


def test_empirical_alpha_helper(key):
    x = jax.random.normal(key, (16, 16))
    a = C.empirical_alpha(C.TopK(0.25), key, x)
    assert 0.25 <= a <= 1.0
