"""Import hypothesis if available; otherwise provide stub decorators so
only the property tests skip — the plain oracle tests in the same files
still run. (A module-level importorskip would silently drop every test
in the file, including the kernel/model oracles that need no hypothesis.)
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Builds inert placeholders for strategy expressions evaluated at
        decoration time (never executed: @given is a skip)."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
