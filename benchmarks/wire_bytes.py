"""Wire-bytes smoke: analytic Table-2 accounting vs the exact bytes the
fused repro.wire buffers move, on the paper's NanoGPT-124M shapes —
BOTH directions (w2s payload gather and s2w model-update broadcast, §9).

Per compressor (bf16 wire, same compressor on both legs):

  dense          uncompressed message bytes
  analytic       LayerPlan.w2s_bytes_per_worker — the paper's Table-2
                 convention (4-byte indices)
  wire           w2s WireLayout.total_nbytes — the fused uint8 buffer
                 the payload all-gather actually moves
  s2w_analytic   LayerPlan.s2w_bytes_per_round (same convention)
  s2w_wire       s2w WireLayout.total_nbytes — what the model-update
                 broadcast moves per round
  two_way_*      the per-round totals the bidirectional account sums to

plus eval_shape checks that packing really produces buffers of exactly
those byte counts, and concrete pack/unpack round-trips (bit-exact) with
wall-clock timings. The ``*_vs_analytic <= 1.15`` bounds are asserted in
``run()`` so every harness (CI fast job included) enforces them.

    PYTHONPATH=src python -m benchmarks.wire_bytes [--out BENCH_wire.json]
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.muon import EF21Muon, EF21MuonConfig
from repro.models.api import abstract_params, build_model
from repro.wire.codecs import NarrowIntCodec

COMPRESSORS = ("top10+natural", "top10", "natural", "rank10+natural")


def _synth_payloads(layout, n_workers: int = 1, seed: int = 0) -> list:
    """Valid (round-trippable) payloads straight from the offset table:
    narrow-index leaves stay inside their byte-width domain, everything
    else is arbitrary bits."""
    key = jax.random.key(seed)
    out = []
    for spec in layout.specs:
        leaves = []
        for c in spec.codecs:
            shape = (n_workers,) + spec.stack_shape + tuple(c.shape)
            n = int(math.prod(shape)) if shape else 1
            if isinstance(c, NarrowIntCodec):
                leaves.append((jnp.arange(n, dtype=jnp.int32)
                               % (1 << (8 * c.width))).reshape(shape))
            else:
                dt = jnp.dtype(c.dtype)
                if jnp.issubdtype(dt, jnp.integer):
                    leaves.append((jnp.arange(n) % 251).astype(dt
                                                               ).reshape(shape))
                else:
                    key, sub = jax.random.split(key)
                    leaves.append(jax.random.normal(
                        sub, shape, jnp.float32).astype(dt))
        out.append(spec.treedef.unflatten(leaves))
    return out


def run(fast: bool = False):
    cfg = get_config("nanogpt-124m")
    model = build_model(cfg)
    shapes, metas = abstract_params(model)
    wire_dt = jnp.bfloat16
    rows = []
    comps = COMPRESSORS[:1] if fast else COMPRESSORS
    def _roundtrip(layout):
        """Concrete pack/unpack round-trip + wall-clock timings."""
        payloads = _synth_payloads(layout)
        pack = jax.jit(layout.pack)
        unpack = jax.jit(layout.unpack)
        buf = jax.block_until_ready(pack(payloads))
        t0 = time.time()
        buf = jax.block_until_ready(pack(payloads))
        t_pack = time.time() - t0
        back = unpack(buf)
        jax.block_until_ready(back)
        t0 = time.time()
        jax.block_until_ready(unpack(buf))
        t_unpack = time.time() - t0
        exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for pa, pb in zip(payloads, back)
            for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
        return bool(exact), t_pack, t_unpack

    for name in comps:
        opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s=name, s2w=name,
                                      wire_dtype=wire_dt))
        plan = opt.plan(shapes, metas)
        layout = plan.wire_layout(wire_dt)
        s2w_layout = plan.wire_layout(wire_dt, direction="s2w")
        dense = plan.dense_bytes(wire_dt)
        analytic = plan.w2s_bytes_per_worker(wire_dt)
        s2w_analytic = plan.s2w_bytes_per_round(wire_dt)
        wire = layout.total_nbytes
        s2w_wire = s2w_layout.total_nbytes
        # the buffers the step would gather/broadcast are exactly the
        # layout byte counts, in both directions
        structs = layout.payload_structs(n_workers=1)
        buf_struct = jax.eval_shape(layout.pack, structs)
        assert buf_struct.shape == (1, wire) and buf_struct.dtype == jnp.uint8
        s_struct = jax.eval_shape(s2w_layout.pack,
                                  s2w_layout.payload_structs(n_workers=1))
        assert s_struct.shape == (1, s2w_wire) \
            and s_struct.dtype == jnp.uint8
        exact, t_pack, t_unpack = _roundtrip(layout)
        s2w_exact, _, _ = _roundtrip(s2w_layout)
        rows.append({
            "bench": "wire", "arch": cfg.name, "w2s": name, "s2w": name,
            "wire": "bf16",
            "dense_bytes": dense, "analytic_bytes": analytic,
            "wire_bytes": wire,
            "s2w_analytic_bytes": s2w_analytic,
            "s2w_wire_bytes": s2w_wire,
            "two_way_analytic_bytes": analytic + s2w_analytic,
            "two_way_wire_bytes": wire + s2w_wire,
            "wire_vs_analytic": round(wire / analytic, 4),
            "s2w_vs_analytic": round(s2w_wire / s2w_analytic, 4),
            "two_way_vs_analytic": round(
                (wire + s2w_wire) / (analytic + s2w_analytic), 4),
            "wire_vs_dense": round(wire / dense, 4),
            "analytic_vs_dense": round(analytic / dense, 4),
            "roundtrip_exact": bool(exact),
            "s2w_roundtrip_exact": bool(s2w_exact),
            "pack_s": round(t_pack, 4), "unpack_s": round(t_unpack, 4)})
    # the CI bounds live here so every harness enforces them
    for r in rows:
        assert r["roundtrip_exact"] and r["s2w_roundtrip_exact"], r
        assert r["wire_vs_analytic"] <= 1.15, r
        assert r["s2w_vs_analytic"] <= 1.15, r
        assert r["two_way_vs_analytic"] <= 1.15, r
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_wire.json")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    for r in rows:
        print(json.dumps(r), flush=True)
    with open(args.out, "w") as f:
        json.dump({"bench": "wire_bytes", "rows": rows}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
