"""Wire-bytes smoke: analytic Table-2 accounting vs the exact bytes the
fused repro.wire buffer moves, on the paper's NanoGPT-124M shapes.

Three numbers per compressor (all per worker->server message, bf16 wire):

  dense     uncompressed message bytes
  analytic  LayerPlan.w2s_bytes_per_worker — the paper's Table-2
            convention (4-byte indices)
  wire      WireLayout.total_nbytes — the fused uint8 buffer the payload
            all-gather actually moves (narrow indices, 9-bit Natural)

plus an eval_shape check that packing really produces a buffer of
exactly ``wire`` bytes, and a concrete pack/unpack round-trip (bit-exact)
with wall-clock timings to start the perf trajectory.

    PYTHONPATH=src python -m benchmarks.wire_bytes [--out BENCH_wire.json]
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.muon import EF21Muon, EF21MuonConfig
from repro.models.api import abstract_params, build_model
from repro.wire.codecs import NarrowIntCodec

COMPRESSORS = ("top10+natural", "top10", "natural", "rank10+natural")


def _synth_payloads(layout, n_workers: int = 1, seed: int = 0) -> list:
    """Valid (round-trippable) payloads straight from the offset table:
    narrow-index leaves stay inside their byte-width domain, everything
    else is arbitrary bits."""
    key = jax.random.key(seed)
    out = []
    for spec in layout.specs:
        leaves = []
        for c in spec.codecs:
            shape = (n_workers,) + spec.stack_shape + tuple(c.shape)
            n = int(math.prod(shape)) if shape else 1
            if isinstance(c, NarrowIntCodec):
                leaves.append((jnp.arange(n, dtype=jnp.int32)
                               % (1 << (8 * c.width))).reshape(shape))
            else:
                dt = jnp.dtype(c.dtype)
                if jnp.issubdtype(dt, jnp.integer):
                    leaves.append((jnp.arange(n) % 251).astype(dt
                                                               ).reshape(shape))
                else:
                    key, sub = jax.random.split(key)
                    leaves.append(jax.random.normal(
                        sub, shape, jnp.float32).astype(dt))
        out.append(spec.treedef.unflatten(leaves))
    return out


def run(fast: bool = False):
    cfg = get_config("nanogpt-124m")
    model = build_model(cfg)
    shapes, metas = abstract_params(model)
    wire_dt = jnp.bfloat16
    rows = []
    comps = COMPRESSORS[:1] if fast else COMPRESSORS
    for name in comps:
        opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s=name,
                                      wire_dtype=wire_dt))
        plan = opt.plan(shapes, metas)
        layout = plan.wire_layout(wire_dt)
        dense = plan.dense_bytes(wire_dt)
        analytic = plan.w2s_bytes_per_worker(wire_dt)
        wire = layout.total_nbytes
        # the buffer the step would all-gather is exactly `wire` bytes
        structs = layout.payload_structs(n_workers=1)
        buf_struct = jax.eval_shape(layout.pack, structs)
        assert buf_struct.shape == (1, wire) and buf_struct.dtype == jnp.uint8
        # concrete round-trip + timing
        payloads = _synth_payloads(layout)
        pack = jax.jit(layout.pack)
        unpack = jax.jit(layout.unpack)
        buf = jax.block_until_ready(pack(payloads))
        t0 = time.time()
        buf = jax.block_until_ready(pack(payloads))
        t_pack = time.time() - t0
        back = unpack(buf)
        jax.block_until_ready(back)
        t0 = time.time()
        jax.block_until_ready(unpack(buf))
        t_unpack = time.time() - t0
        exact = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for pa, pb in zip(payloads, back)
            for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
        rows.append({
            "bench": "wire", "arch": cfg.name, "w2s": name, "wire": "bf16",
            "dense_bytes": dense, "analytic_bytes": analytic,
            "wire_bytes": wire,
            "wire_vs_analytic": round(wire / analytic, 4),
            "wire_vs_dense": round(wire / dense, 4),
            "analytic_vs_dense": round(analytic / dense, 4),
            "roundtrip_exact": bool(exact),
            "pack_s": round(t_pack, 4), "unpack_s": round(t_unpack, 4)})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_wire.json")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    for r in rows:
        print(json.dumps(r), flush=True)
        assert r["roundtrip_exact"], r
        assert r["wire_vs_analytic"] <= 1.15, r
    with open(args.out, "w") as f:
        json.dump({"bench": "wire_bytes", "rows": rows}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
