"""Table 2 reproduction: per-round w2s communication cost (bytes),
normalised to the identity compressor, on the paper's NanoGPT-124M
parameter shapes.

The paper's numbers use f32 wires (PyTorch DDP); our TPU wire format is
bf16, so both conventions are reported. The paper's Table 2:

  ID 1.0 | Natural 0.5 | Rank20% 0.2687 | Rank15% 0.2019 |
  Rank15%+Nat 0.1010 | Rank10% 0.1335 | Rank10%+Nat 0.0667 |
  Rank5% 0.0667 | Top20% 0.3625 | Top15% 0.2718 | Top15%+Nat 0.1969 |
  Top10% 0.1812 | Top10%+Nat 0.1312 | Top5% 0.0906
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.muon import EF21Muon, EF21MuonConfig
from repro.models.api import build_model

PAPER_TABLE2 = {
    "identity": 1.0, "natural": 0.5,
    "rank20": 0.2687, "rank15": 0.2019, "rank15+natural": 0.1010,
    "rank10": 0.1335, "rank10+natural": 0.0667, "rank5": 0.0667,
    "top20": 0.3625, "top15": 0.2718, "top15+natural": 0.1969,
    "top10": 0.1812, "top10+natural": 0.1312, "top5": 0.0906,
}


def run(fast: bool = False):
    cfg = get_config("nanogpt-124m")
    model = build_model(cfg)
    box = {}

    def initp(k):
        p, m = model.init(k)
        box["m"] = m
        return p

    shapes = jax.eval_shape(initp, jax.random.key(0))
    metas = box["m"]
    rows = []
    # f32 wire = the paper's convention; bf16 = our TPU wire format
    for wire, wname in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
        dense = None
        for comp in PAPER_TABLE2:
            opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s=comp,
                                          wire_dtype=wire))
            b = opt.w2s_bytes_per_worker(shapes, metas)
            if comp == "identity":
                dense = b
            rel = b / dense
            paper = PAPER_TABLE2[comp]
            rows.append({
                "bench": "table2", "wire": wname, "compressor": comp,
                "bytes": b, "relative": round(rel, 4),
                "paper_relative": paper,
                "abs_err": round(abs(rel - paper), 4)})
    return rows
