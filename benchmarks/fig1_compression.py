"""Figure 1/2 reproduction (CPU scale): EF21-Muon with the paper's
compressor zoo vs the uncompressed baseline (= Gluon/Scion) on a reduced
NanoGPT trained over the synthetic Zipf-Markov corpus with 4 heterogeneous
workers.

Reports, per compressor: steps/tokens to reach the target loss and the
w2s bytes sent per worker to reach it — the paper's claim is that the
Rank/Top(+Natural) compressors reach the same loss with 4-7x fewer w2s
bytes (Figure 1 right, Figure 2).
"""
from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.schedule import warmup_linear_decay
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.train.trainer import Trainer, TrainerConfig

COMPRESSORS = ["identity", "natural", "top10", "top15+natural",
               "rank10", "rank15+natural"]


def run(fast: bool = False):
    cfg = get_config("nanogpt-124m").reduced()
    model = build_model(cfg)
    n_workers = 4
    seq, batch = (32, 8) if fast else (64, 16)
    max_steps = 60 if fast else 220
    target = 5.4 if fast else 4.4
    shape = ShapeSpec("f", "train", seq, batch)
    data = SyntheticLM(cfg, shape, n_workers=n_workers, seed=0)
    tokens_per_step = seq * batch
    rows = []
    for comp in COMPRESSORS:
        tr = Trainer(model, TrainerConfig(
            n_workers=n_workers, beta=0.7, w2s=comp, remat=False,
            use_pallas=False))
        state = tr.init(jax.random.key(0))
        wire = tr.opt.w2s_bytes_per_worker(state["x"], tr.metas)
        step = jax.jit(tr.make_step())
        sched = warmup_linear_decay(0.01, 8, max_steps, final_frac=0.3)
        t0 = time.time()
        reached = None
        loss = float("nan")
        for i in range(max_steps):
            state, aux = step(state, data.batch_at(i), sched(i))
            loss = float(aux["loss"])
            if loss <= target:
                reached = i + 1
                break
        steps = reached if reached else max_steps
        rows.append({
            "bench": "fig1", "compressor": comp,
            "target_loss": target, "reached": bool(reached),
            "final_loss": round(loss, 3), "steps": steps,
            "tokens": steps * tokens_per_step,
            "w2s_bytes_per_step": wire,
            "w2s_bytes_to_target": steps * wire,
            "wall_s": round(time.time() - t0, 1)})
    # savings vs uncompressed baseline (Figure 1 right)
    base = next(r for r in rows if r["compressor"] == "identity")
    for r in rows:
        r["byte_savings_vs_id"] = round(
            base["w2s_bytes_to_target"] / r["w2s_bytes_to_target"], 2)
        r["token_overhead_vs_id"] = round(
            r["tokens"] / base["tokens"], 2)
    return rows
