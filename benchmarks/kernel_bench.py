"""Kernel micro-benchmarks: the jnp oracle path (the CPU execution path)
timed per call, plus correctness deltas of the Pallas path (interpret
mode — Pallas timing on CPU is not meaningful, the TARGET is TPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import natural_compress, newton_schulz


def _first(out):
    return out[0] if isinstance(out, tuple) else out


def _time(fn, *args, reps=5):
    _first(fn(*args)).block_until_ready()   # single warm-up call
    t0 = time.perf_counter()
    for _ in range(reps):
        _first(fn(*args)).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False):
    rows = []
    key = jax.random.key(0)
    shapes = [(256, 256), (512, 512)] if fast else \
        [(256, 256), (512, 512), (1024, 1024), (768, 3072)]
    ns_ref = jax.jit(lambda g: ref.newton_schulz_ref(g, steps=5))
    for shape in shapes:
        g = jax.random.normal(key, shape, jnp.float32)
        us = _time(ns_ref, g)
        # Pallas correctness delta (interpret mode)
        got = newton_schulz(g, steps=5, use_pallas=True, interpret=True)
        want = ref.newton_schulz_ref(g, steps=5)
        err = float(jnp.max(jnp.abs(got - want)))
        flops = 5 * 3 * 2 * min(shape) ** 2 * max(shape)
        rows.append({"bench": "kernels", "kernel": "newton_schulz",
                     "shape": f"{shape[0]}x{shape[1]}",
                     "us_per_call_ref": round(us, 1),
                     "gflops_ref": round(flops / us / 1e3, 1),
                     "pallas_max_abs_err": err})
    n = 1 << (16 if fast else 20)
    x = jax.random.normal(key, (n,)).astype(jnp.bfloat16)
    nat = jax.jit(lambda x: natural_compress(x, use_pallas=False))
    us = _time(nat, x)
    rows.append({"bench": "kernels", "kernel": "natural_compress",
                 "shape": str(n), "us_per_call_ref": round(us, 1),
                 "gbps_ref": round(n * 2 / us / 1e3, 2)})
    return rows
