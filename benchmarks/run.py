"""Benchmark harness — one module per paper table/figure plus the
roofline report. Prints JSON rows per benchmark.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ("table2", "wire", "ns", "step", "ef_necessity", "convergence",
           "kernels", "fig1", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None, help=f"run one of {BENCHES}")
    args = ap.parse_args()

    from benchmarks import (convergence, ef_necessity, fig1_compression,
                            kernel_bench, ns_bench, roofline_report,
                            step_bench, table2_bytes, wire_bytes)
    mods = {"table2": table2_bytes, "wire": wire_bytes, "ns": ns_bench,
            "step": step_bench, "ef_necessity": ef_necessity,
            "convergence": convergence, "kernels": kernel_bench,
            "fig1": fig1_compression, "roofline": roofline_report}
    names = [args.only] if args.only else list(BENCHES)
    failures = 0
    for name in names:
        t0 = time.time()
        print(f"### {name}", flush=True)
        try:
            rows = mods[name].run(fast=args.fast)
            for r in rows:
                print(json.dumps(r), flush=True)
        except Exception as e:
            failures += 1
            print(json.dumps({"bench": name, "status": "error",
                              "error": f"{type(e).__name__}: {e}"}),
                  flush=True)
        print(f"### {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
