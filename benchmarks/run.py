"""Benchmark harness — one module per paper table/figure plus the
roofline report. Prints JSON rows per benchmark and writes one
``BENCH_<name>.json`` artifact per benchmark into the repo root (the
committed perf trajectory; fast CI refreshes them every run).

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME[,NAME]]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = ("table2", "wire", "ns", "step", "ef_necessity", "convergence",
           "elastic", "resync", "kernels", "fig1", "roofline")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {BENCHES}")
    ap.add_argument("--out-dir", default=REPO_ROOT,
                    help="where the BENCH_<name>.json artifacts go "
                         "(default: the repo root)")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="print rows only, write no BENCH_*.json")
    args = ap.parse_args()

    from types import SimpleNamespace

    from benchmarks import (convergence, ef_necessity, fig1_compression,
                            kernel_bench, ns_bench, resync_soak,
                            roofline_report, step_bench, table2_bytes,
                            wire_bytes)
    mods = {"table2": table2_bytes, "wire": wire_bytes, "ns": ns_bench,
            "step": step_bench, "ef_necessity": ef_necessity,
            "convergence": convergence,
            "elastic": SimpleNamespace(run=convergence.run_elastic),
            "resync": resync_soak,
            "kernels": kernel_bench,
            "fig1": fig1_compression, "roofline": roofline_report}
    names = [n.strip() for n in args.only.split(",") if n.strip()] \
        if args.only else list(BENCHES)
    unknown = [n for n in names if n not in mods]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from {BENCHES}")
    failures = 0
    for name in names:
        t0 = time.time()
        print(f"### {name}", flush=True)
        try:
            rows = mods[name].run(fast=args.fast)
            for r in rows:
                print(json.dumps(r), flush=True)
            if not args.no_artifacts:
                out = os.path.join(args.out_dir, f"BENCH_{name}.json")
                # one exit point for all BENCH artifacts: every row is
                # validated against the sink's bench schema before the
                # envelope is written (DESIGN.md §10)
                from repro.obs.sink import write_bench_artifact
                write_bench_artifact(out, name, rows, fast=args.fast)
                print(f"wrote {out}", flush=True)
        except Exception as e:
            failures += 1
            print(json.dumps({"bench": name, "status": "error",
                              "error": f"{type(e).__name__}: {e}"}),
                  flush=True)
        print(f"### {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
