"""End-to-end step bench (BENCH_step.json): wall-time of one fully
jitted EF21-Muon train step on the paper's NanoGPT-124M, staged wire
pipeline vs monolithic gather (DESIGN.md §8), plus the overlap-aware
roofline numbers from the compiled HLO.

Runs in a subprocess on 8 emulated host devices (a (4 data, 2 model)
mesh with 4 EF21 workers) so the lowered step contains the real payload
all-gathers; the jnp (use_pallas=False) path keeps it backend-portable.
Two arms per run:

  staged      wire_stages="auto"  — K payload gathers, K = stages
  monolithic  wire_stages=1       — the single blocking gather (PR-4 arm)

Per arm: µs/step (median of ``reps`` timed steps after a warm-up),
compile time, measured u8 gather count/bytes, and the exposed-collective
roofline term; the staged arm records the staged/monolithic ratios. The
timed loop calls the AOT-compiled executable directly — it structurally
cannot re-trace or re-compile, and the in-script spread assertion
(max <= 1.5 x min + slack) proves the warm window contains no
compile-scale outlier; ``t_warm_s`` records the first post-compile call
separately. The
exposed-collective ratio is asserted < 1 (the §8 win is structural —
scheduling, not noise); wall-time is recorded but NOT gated, because on
the CPU backend collectives are memcpys and the two arms lower the same
math.

    PYTHONPATH=src python -m benchmarks.step_bench [--fast] [--out ...]

``--fast`` (the CI setting) runs the reduced NanoGPT (2 layers, 256-wide,
512-vocab — full-width compiles take tens of minutes on emulated host
devices) so the fast job stays fast; the full-size row is the local perf
trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# the staged/monolithic exposed-collective acceptance bound is shared
# with the slow job's SPMD A/B — one constant, one place to move it
# (imported lazily in main(); ns_bench pulls in jax at import time)

STEP_SCRIPT = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.launch.hlo_analysis import overlap_roofline_terms
from repro.launch.hlo_cost import analyze
from repro.models.api import build_model
from repro.train.trainer import Trainer, TrainerConfig

fast = json.loads(sys.argv[1])
cfg = get_config("nanogpt-124m")
arch = cfg.name
if fast:
    # CI-sized: reduced widths/vocab (full-width nanogpt on 8 emulated
    # host devices compiles for tens of minutes — the full-size row is
    # the local trajectory, the reduced one the CI guard)
    cfg = cfg.reduced()
    arch = f"{cfg.name}@reduced"
shape = ShapeSpec("t", "train", 64 if fast else 256, 4 if fast else 8)
reps = 3 if fast else 5
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
model = build_model(cfg)
rows = []
for label, ws in (("staged", "auto"), ("monolithic", 1)):
    tr = Trainer(model, TrainerConfig(
        n_workers=4, beta=0.5, w2s="top10+natural", use_pallas=False,
        remat=False, wire_stages=ws), mesh=mesh)
    data = SyntheticLM(cfg, shape, n_workers=4, seed=0)
    batch = data.batch_at(0)
    bshapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    step = tr.jit_step(bshapes)
    st_sh, b_sh = tr.shardings(bshapes)
    state = tr.init(jax.random.key(0))
    state = jax.device_put(state, st_sh)
    t = jnp.asarray(0.01, jnp.float32)
    t0 = time.time()
    compiled = step.lower(state, batch, t).compile()
    t_compile = time.time() - t0
    a = analyze(compiled.as_text())
    terms = overlap_roofline_terms(a["flops"], a["hbm_bytes"],
                                   a["coll_bytes"], a["coll_pairs"])
    # Time through the AOT executable itself: calling ``compiled``
    # structurally cannot re-trace or re-compile (a signature mismatch
    # is an error, not a silent recompile — the bug this replaces was a
    # weak-typed 0.01 re-jitting a second signature mid-"warm" loop).
    t0 = time.time()
    state, aux = compiled(state, jax.device_put(batch, b_sh), t)  # warm
    jax.block_until_ready(state)
    t_warm = time.time() - t0
    times = []
    for i in range(reps):
        b = jax.device_put(data.batch_at(i + 1), b_sh)
        t0 = time.time()
        state, aux = compiled(state, b, t)
        jax.block_until_ready(state)
        times.append(time.time() - t0)
    # warm window must exclude compile: no step may be compile-scale
    # slower than the fastest (the old failure mode folded a ~25s
    # re-compile into the first "timed" step)
    assert max(times) <= 1.5 * min(times) + 0.25, (label, times)
    plan = tr.layer_plan()
    rows.append({
        "bench": "step", "arch": arch, "arm": label,
        "mesh": "4x2 host", "seq": shape.seq, "batch": shape.batch,
        "n_wire_stages": plan.stage_plan(
            mesh=mesh, wire_stages=ws).n_stages if ws != 1 else 1,
        "us_per_step": round(1e6 * sorted(times)[len(times) // 2], 1),
        "t_compile_s": round(t_compile, 1),
        "t_warm_s": round(t_warm, 3),
        "loss": float(aux["loss"]),
        "u8_count": a["u8_coll_count"], "u8_bytes": a["u8_coll_bytes"],
        "wire_bytes": plan.wire_layout(tr.opt.cfg.wire_dtype).total_nbytes,
        "t_collective_s": terms["t_collective_s"],
        "t_exposed_collective_s": terms["t_exposed_collective_s"],
        "hidden_collective_frac": round(
            terms["hidden_collective_frac"], 4),
        "bottleneck_overlap": terms["bottleneck_overlap"],
    })
staged, mono = rows
staged["exposed_collective_ratio"] = round(
    staged["t_exposed_collective_s"] / mono["t_exposed_collective_s"], 4)
staged["step_time_ratio"] = round(
    staged["us_per_step"] / mono["us_per_step"], 4)
print(json.dumps(rows))
"""


def run(fast: bool = False) -> list[dict]:
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    root = os.path.join(os.path.dirname(__file__), "..")
    out = subprocess.run(
        [sys.executable, "-c", STEP_SCRIPT, json.dumps(bool(fast))],
        capture_output=True, text=True, cwd=root, env=env, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"step_bench subprocess failed:\n{out.stderr[-3000:]}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    staged, mono = rows
    # structural invariants (the §8 acceptance, small-mesh edition)
    assert staged["n_wire_stages"] > 1, rows
    assert staged["u8_count"] == staged["n_wire_stages"], rows
    assert mono["u8_count"] == 1, rows
    assert staged["u8_bytes"] == mono["u8_bytes"] \
        == staged["wire_bytes"], rows
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_step.json")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    for r in rows:
        print(json.dumps(r), flush=True)
    from benchmarks.ns_bench import PIPELINE_EXPOSED_BOUND

    staged = next(r for r in rows if r["arm"] == "staged")
    assert staged["exposed_collective_ratio"] <= PIPELINE_EXPOSED_BOUND, \
        staged
    from repro.obs.sink import write_bench_artifact
    write_bench_artifact(args.out, "step_bench", rows, fast=args.fast)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
