"""EF-necessity ablation (paper §2 / Beznosikov et al. Example 1):
biased compression *without* error feedback stalls or diverges; the EF21
mechanism converges. Run on the 3-quadratic construction and on a tiny LM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.compressors import TopK
from repro.core.error_feedback import ef_compress_step


def run(fast: bool = False):
    a = jnp.array([[-3.0, 2.0, 2.0], [2.0, -3.0, 2.0], [2.0, 2.0, -3.0]])

    def grad_j(x, j):
        return x + jnp.eye(3)[j] * x[j] + a[j]

    def full_grad(x):
        return jnp.mean(jnp.stack([grad_j(x, j) for j in range(3)]), 0)

    comp = TopK(0.34)
    lr, steps = 0.1, 100 if fast else 400
    x0 = jnp.array([1.0, 0.7, -0.3])

    x = x0
    for _ in range(steps):
        g = jnp.mean(jnp.stack([
            comp.decompress(comp.compress({}, grad_j(x, j))[0], (3,),
                            jnp.float32) for j in range(3)]), 0)
        x = x - lr * g
    naive_gn = float(jnp.linalg.norm(full_grad(x)))

    x = x0
    G = [jnp.zeros(3)] * 3
    for _ in range(steps):
        for j in range(3):
            _, _, G[j] = ef_compress_step(comp, {}, G[j], grad_j(x, j),
                                          jnp.float32)
        x = x - lr * jnp.mean(jnp.stack(G), 0)
    ef_gn = float(jnp.linalg.norm(full_grad(x)))

    x = x0
    for _ in range(steps):
        x = x - lr * full_grad(x)
    exact_gn = float(jnp.linalg.norm(full_grad(x)))

    return [{"bench": "ef_necessity", "method": "top1_no_ef",
             "grad_norm": naive_gn, "converged": naive_gn < 1e-2},
            {"bench": "ef_necessity", "method": "top1_ef21",
             "grad_norm": ef_gn, "converged": ef_gn < 1e-2},
            {"bench": "ef_necessity", "method": "exact_gd",
             "grad_norm": exact_gn, "converged": exact_gn < 1e-2}]
