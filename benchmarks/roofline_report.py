"""Roofline report: reads results/dryrun.jsonl (written by
repro.launch.dryrun) and emits the per-(arch x shape x mesh) three-term
roofline table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "results/dryrun.jsonl")


def load(path: str = RESULTS, tag: str | None = None):
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if tag and r.get("tag") != tag:
                continue
            recs[(r["arch"], r["shape"], r["mesh"], r.get("tag"))] = r
    return list(recs.values())


def run(fast: bool = False):
    rows = []
    for r in sorted(load(), key=lambda r: (r["arch"], r["shape"],
                                           r["mesh"])):
        row = {"bench": "roofline", "arch": r["arch"], "shape": r["shape"],
               "mesh": r["mesh"], "tag": r.get("tag"),
               "status": r["status"]}
        if r["status"] == "ok":
            row.update({
                "t_compute_s": round(r["t_compute_s"], 5),
                "t_memory_s": round(r["t_memory_s"], 5),
                "t_collective_s": round(r["t_collective_s"], 5),
                "bottleneck": r["bottleneck"],
                "useful_flops_ratio": round(r["useful_flops_ratio"] or 0,
                                            3),
                "coll_gb": round(r["coll_bytes"] / 1e9, 3),
                "peak_gb": round(r.get("memory", {}).get(
                    "peak_bytes", 0) / 1e9, 2)})
        elif r["status"] == "skipped":
            row["reason"] = r.get("reason", "")[:60]
        else:
            row["error"] = r.get("error", "")[:80]
        rows.append(row)
    return rows


def markdown_table(tag: str = "baseline") -> str:
    recs = sorted(load(tag=tag), key=lambda r: (r["arch"], r["shape"],
                                                r["mesh"]))
    lines = [
        "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bottleneck | useful FLOPs | coll GB/dev | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} | "
                f"{r['t_collective_s']:.4g} | **{r['bottleneck']}** | "
                f"{(r['useful_flops_ratio'] or 0):.2f} | "
                f"{r['coll_bytes'] / 1e9:.2f} | "
                f"{r.get('memory', {}).get('peak_bytes', 0) / 1e9:.1f} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped | — | — | — |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | | |")
    return "\n".join(lines)
