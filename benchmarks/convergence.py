"""Convergence-rate trend check (Theorems 3/4): deterministic EF21-Muon on
a smooth non-convex problem should drive min_k ||grad||_* at ~O(1/sqrt(K))
— we verify the log-log slope of the running-min gradient norm is <= -0.4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta


def run(fast: bool = False):
    key = jax.random.key(0)
    T = jax.random.normal(key, (16, 16))

    def loss(x):
        # smooth non-convex: quadratic + cosine ripple
        d = x - T
        return 0.5 * jnp.sum(d * d) + jnp.sum(jnp.cos(x)) * 0.5

    def gal(p, b):
        return loss(p), jax.grad(loss)(p)

    metas = ParamMeta("spectral", 1.0, 0)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, beta=1.0, w2s="top15",
                                  use_pallas=False))
    state = opt.init(key, jnp.zeros((16, 16)), metas)
    step = opt.make_step(metas)
    K = 150 if fast else 500
    batch = jnp.zeros((1, 1))
    eta = 1.0
    gnorms = []
    for k in range(K):
        t = eta / np.sqrt(K + 1)  # Theorem 4 radii
        state, _ = step(state, gal, batch, t)
        g = jax.grad(loss)(state["x"])
        gnorms.append(float(jnp.sum(jnp.linalg.svd(
            g, compute_uv=False))))  # nuclear = dual of spectral
    run_min = np.minimum.accumulate(gnorms)
    ks = np.arange(1, K + 1)
    sl = np.polyfit(np.log(ks[K // 10:]), np.log(run_min[K // 10:] + 1e-9),
                    1)[0]
    return [{"bench": "convergence", "K": K,
             "final_min_dual_grad_norm": float(run_min[-1]),
             "loglog_slope": round(float(sl), 3),
             "matches_theory": bool(sl <= -0.35)}]
