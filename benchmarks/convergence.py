"""Convergence-rate trend check (Theorems 3/4): deterministic EF21-Muon on
a smooth non-convex problem should drive min_k ||grad||_* at ~O(1/sqrt(K))
— we verify the log-log slope of the running-min gradient norm is <= -0.4.

``run_elastic`` is the partial-participation arm (DESIGN.md §11, the
Gluon-FL degradation claim): the same heterogeneous quadratic under
bernoulli(p) participation for p in {1.0, 0.75, 0.5} — convergence
degrades gracefully with p (frozen EF21 state + dynamic-count fold), it
does not diverge. Emitted as ``BENCH_elastic.json`` via benchmarks/run.py
through the repro.metrics/v1 bench schema.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta


def run(fast: bool = False):
    key = jax.random.key(0)
    T = jax.random.normal(key, (16, 16))

    def loss(x):
        # smooth non-convex: quadratic + cosine ripple
        d = x - T
        return 0.5 * jnp.sum(d * d) + jnp.sum(jnp.cos(x)) * 0.5

    def gal(p, b):
        return loss(p), jax.grad(loss)(p)

    metas = ParamMeta("spectral", 1.0, 0)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, beta=1.0, w2s="top15",
                                  use_pallas=False))
    state = opt.init(key, jnp.zeros((16, 16)), metas)
    step = opt.make_step(metas)
    K = 150 if fast else 500
    batch = jnp.zeros((1, 1))
    eta = 1.0
    gnorms = []
    for k in range(K):
        t = eta / np.sqrt(K + 1)  # Theorem 4 radii
        state, _ = step(state, gal, batch, t)
        g = jax.grad(loss)(state["x"])
        gnorms.append(float(jnp.sum(jnp.linalg.svd(
            g, compute_uv=False))))  # nuclear = dual of spectral
    run_min = np.minimum.accumulate(gnorms)
    ks = np.arange(1, K + 1)
    sl = np.polyfit(np.log(ks[K // 10:]), np.log(run_min[K // 10:] + 1e-9),
                    1)[0]
    return [{"bench": "convergence", "K": K,
             "final_min_dual_grad_norm": float(run_min[-1]),
             "loglog_slope": round(float(sl), 3),
             "matches_theory": bool(sl <= -0.35)}]


def run_elastic(fast: bool = False):
    """Elastic-participation arm: 4 heterogeneous workers, bernoulli(p)
    participation, one row per p in {1.0, 0.75, 0.5}."""
    key = jax.random.key(0)
    n_w = 4
    Ts = jax.random.normal(key, (n_w, 16, 16))
    opt_pt = jnp.mean(Ts, axis=0)    # minimiser of the average quadratic

    def gal(p, wb):
        t = Ts[jnp.int32(wb[0])]
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    metas = ParamMeta("spectral", 1.0, 0)
    batch = jnp.arange(float(n_w)).reshape(n_w, 1)
    K = 60 if fast else 200
    rows = []
    for p in (1.0, 0.75, 0.5):
        spec = "full" if p == 1.0 else f"bernoulli({p})"
        opt = EF21Muon(EF21MuonConfig(
            n_workers=n_w, beta=0.5, w2s="top10", use_pallas=False,
            participation=spec))
        state = opt.init(key, jnp.zeros((16, 16)), metas)
        step = jax.jit(lambda s, b, o=opt: o.make_step(metas)(
            s, gal, b, 0.05))
        n_part = []
        for _ in range(K):
            state, aux = step(state, batch)
            n_part.append(float(aux.get("n_participants", n_w)))
        err = float(jnp.linalg.norm(state["x"] - opt_pt)
                    / jnp.linalg.norm(opt_pt))
        rows.append({
            "bench": "elastic", "p": p, "participation": spec, "K": K,
            "final_rel_err": round(err, 4),
            "mean_participants": round(float(np.mean(n_part)), 3),
            "final_loss": round(float(aux["loss"]), 4),
            "all_finite": bool(all(
                jnp.all(jnp.isfinite(lf)) for lf in jax.tree.leaves(state)
                if jnp.issubdtype(lf.dtype, jnp.inexact))),
            "converged": bool(err < 0.5)})
    return rows
