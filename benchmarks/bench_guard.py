"""Bench-regression guard (fast CI): fresh ``BENCH_*.json`` artifacts vs
the committed baselines.

The fast job regenerates ``BENCH_step.json`` / ``BENCH_wire.json`` into
the workspace (overwriting the checkout), so the committed baseline is
read from git (``git show <ref>:BENCH_x.json``) and compared row-by-row:

  * wire bytes (exact static accounting: ``wire_bytes``,
    ``s2w_wire_bytes``, ``two_way_wire_bytes``, ``u8_bytes``) — ANY
    increase fails: payload accounting is deterministic, a byte
    regression is a real compression/packing regression.
  * ``us_per_step`` — fails beyond ``--step-tol`` (default 10%). Wall
    time is machine-dependent; CI overrides the tolerance via
    ``BENCH_GUARD_STEP_TOL`` because runner hardware differs from the
    machine that produced the committed baseline.

Rows are matched by stable identity keys (arch + arm for step, arch +
compressor pair + wire dtype for wire); unmatched fresh rows are new
coverage and pass. Output is a one-line-per-metric diff table.

    PYTHONPATH=src python -m benchmarks.bench_guard [--fresh-dir .]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# file -> (identity key fields, wall-time fields, exact byte fields)
GUARDS = {
    "BENCH_step.json": {
        "key": ("arch", "arm"),
        "time": ("us_per_step",),
        "bytes": ("u8_bytes", "wire_bytes"),
    },
    "BENCH_wire.json": {
        "key": ("arch", "w2s", "s2w", "wire"),
        "time": (),
        "bytes": ("wire_bytes", "s2w_wire_bytes", "two_way_wire_bytes"),
    },
}


def load_baseline(name: str, ref: str, root: str) -> dict | None:
    out = subprocess.run(["git", "show", f"{ref}:{name}"], cwd=root,
                         capture_output=True, text=True)
    if out.returncode != 0:
        return None
    return json.loads(out.stdout)


def _index(rows: list[dict], key_fields: tuple) -> dict:
    return {tuple(r.get(k) for k in key_fields): r for r in rows}


def compare(name: str, base: dict, fresh: dict,
            step_tol: float) -> tuple[list[str], int]:
    """Diff one artifact; returns (table lines, failure count)."""
    spec = GUARDS[name]
    base_ix = _index(base["rows"], spec["key"])
    lines, failures = [], 0
    for key, frow in _index(fresh["rows"], spec["key"]).items():
        brow = base_ix.get(key)
        kid = "/".join(str(k) for k in key)
        if brow is None:
            lines.append(f"{name} {kid}: new row (no baseline) .. PASS")
            continue
        for metric in spec["bytes"]:
            if metric not in frow and metric not in brow:
                continue
            b, f = brow.get(metric), frow.get(metric)
            ok = b is None or f is None or f <= b
            failures += 0 if ok else 1
            lines.append(_line(name, kid, metric, b, f,
                               "PASS" if ok else "FAIL (byte regression)"))
        for metric in spec["time"]:
            b, f = brow.get(metric), frow.get(metric)
            ok = not b or f is None or f <= b * (1 + step_tol)
            failures += 0 if ok else 1
            lines.append(_line(
                name, kid, metric, b, f,
                "PASS" if ok else f"FAIL (> {step_tol:.0%} slower)"))
    return lines, failures


def _line(name, kid, metric, b, f, status) -> str:
    delta = f"{(f - b) / b:+.1%}" if b and f is not None else "n/a"
    return (f"{name} {kid} {metric}: base={b} fresh={f} "
            f"delta={delta} .. {status}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=".",
                    help="where the regenerated BENCH_*.json live")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baselines")
    ap.add_argument("--files", default=",".join(GUARDS),
                    help="comma-separated subset of the guarded artifacts")
    ap.add_argument("--step-tol",
                    type=float,
                    default=float(os.environ.get("BENCH_GUARD_STEP_TOL",
                                                 0.10)),
                    help="allowed relative us_per_step increase "
                         "(env BENCH_GUARD_STEP_TOL overrides the default)")
    args = ap.parse_args()
    from repro.obs.sink import validate_bench_file

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    failures = 0
    for name in (n.strip() for n in args.files.split(",") if n.strip()):
        if name not in GUARDS:
            ap.error(f"unknown artifact {name}; choose from {list(GUARDS)}")
        fresh_path = os.path.join(args.fresh_dir, name)
        if not os.path.exists(fresh_path):
            print(f"{name}: no fresh artifact at {fresh_path} .. "
                  f"FAIL (bench did not run?)")
            failures += 1
            continue
        validate_bench_file(fresh_path)   # schema gate before comparing
        with open(fresh_path) as f:
            fresh = json.load(f)
        base = load_baseline(name, args.baseline_ref, root)
        if base is None:
            print(f"{name}: no committed baseline at "
                  f"{args.baseline_ref} .. PASS (first run)")
            continue
        lines, n_fail = compare(name, base, fresh, args.step_tol)
        print("\n".join(lines))
        failures += n_fail
    print(f"bench_guard: {'FAIL' if failures else 'OK'} "
          f"({failures} regression(s))")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
