"""Newton-Schulz bucketing bench: the perf trajectory of the shape-
bucketed batched NS dispatch (DESIGN.md §7) on the paper's NanoGPT-124M.

Three numbers per run:

  dispatch   traced NS pallas_call counts for ONE full nanogpt-124m
             EF21-Muon step — bucketed (ns_steps x n_buckets), per-leaf
             fused (ns_steps x n_spectral_leaves) and the pre-fusion
             chain (3 x ns_steps x n_spectral_leaves);
  µs/step    wall-clock of the phase-5 spectral NS work on the jnp
             reference path, bucketed vs a per-slice loop, measured at
             nanogpt-124m widths with a reduced layer count (the
             per-slice cost is depth-independent; *_est_full_us
             extrapolates linearly to the full 12-layer batch);
  fused err  interpret-mode max |fused kernel - batched jnp ref| — the
             correctness of the single-pallas_call iteration.

    PYTHONPATH=src python -m benchmarks.ns_bench [--out BENCH_ns.json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.kernel_bench import _time
from repro.configs import get_config
from repro.core.muon import EF21Muon, EF21MuonConfig
from repro.kernels import ref
from repro.kernels.newton_schulz import ns_iteration_fused
from repro.kernels.ops import count_ns_dispatches
from repro.models.api import abstract_params, build_model

NS_STEPS = 5


def _traced_step_ns_calls(cfg, ns_bucketing: bool) -> tuple[int, int, int]:
    """(ns_pallas_calls, n_buckets, n_spectral_leaves) of one traced
    EF21-Muon step on this arch (trace only — nothing is executed)."""
    model = build_model(cfg)
    shapes, metas = abstract_params(model)
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    opt = EF21Muon(EF21MuonConfig(n_workers=1, w2s="top10",
                                  use_pallas=True,
                                  ns_bucketing=ns_bucketing))
    state = opt.init(jax.random.key(0), params, metas)
    step = opt.make_step(metas)

    def gl(p, batch):
        return jax.value_and_grad(lambda q: model.loss(q, batch))(p)

    batch = {"tokens": jnp.zeros((1, 1, 16), jnp.int32),
             "labels": jnp.zeros((1, 1, 16), jnp.int32)}
    jaxpr = jax.make_jaxpr(lambda s, b: step(s, gl, b, 0.01))(state, batch)
    plan = opt.plan(params, metas)
    n_spectral = sum(1 for lp in plan.leaves if lp.meta.lmo == "spectral")
    return (count_ns_dispatches(jaxpr.jaxpr), len(plan.ns_buckets()),
            n_spectral)


def _bucket_stacks(cfg) -> list[tuple[tuple[int, int], int]]:
    """(canonical shape, batch) per NS bucket of this arch."""
    model = build_model(cfg)
    shapes, metas = abstract_params(model)
    opt = EF21Muon(EF21MuonConfig())
    return [(b.shape, b.batch)
            for b in opt.plan(shapes, metas).ns_buckets()]


def run(fast: bool = False):
    cfg = get_config("nanogpt-124m")
    rows = []

    # ---- dispatch counts: full nanogpt-124m, trace level
    bucketed, n_buckets, n_spectral = _traced_step_ns_calls(cfg, True)
    per_leaf, _, _ = _traced_step_ns_calls(cfg, False)
    chain = 3 * NS_STEPS * n_spectral            # the pre-fusion baseline
    # exact-count cross-check: guards the counter itself (a refactor that
    # made it return 0 everywhere would satisfy the <= bound trivially)
    assert per_leaf == NS_STEPS * n_spectral, (per_leaf, n_spectral)
    assert 0 < bucketed <= NS_STEPS * n_buckets, (bucketed, n_buckets)
    rows.append({"bench": "ns", "arch": cfg.name, "kind": "dispatch",
                 "ns_steps": NS_STEPS, "n_buckets": n_buckets,
                 "n_spectral_leaves": n_spectral,
                 "ns_calls_bucketed": bucketed,
                 "ns_calls_per_leaf_fused": per_leaf,
                 "ns_calls_per_leaf_chain": chain,
                 "dispatch_reduction_vs_chain":
                     round(chain / max(bucketed, 1), 2)})

    # ---- µs/step of the spectral NS work, jnp reference path, at
    # nanogpt widths with a reduced layer count (per-slice cost is
    # depth-independent; extrapolated linearly to full depth).
    depth = 1 if fast else 2
    timing_cfg = cfg.with_depth(depth)
    full = dict(_bucket_stacks(cfg))
    key = jax.random.key(0)
    bucketed_us = per_slice_us = est_full_us = 0.0
    reps = 1 if fast else 2
    for shape, batch in _bucket_stacks(timing_cfg):
        g = jax.random.normal(key, (batch,) + shape, jnp.float32) * 0.1
        t_b = _time(jax.jit(
            lambda x: ref.newton_schulz_batched_ref(x, steps=NS_STEPS)), g,
            reps=reps)
        one = jax.jit(lambda x: ref.newton_schulz_ref(x, steps=NS_STEPS))

        def loop(x):
            outs = [one(x[i]) for i in range(x.shape[0])]
            jax.block_until_ready(outs)
            return outs[-1]

        t_p = _time(loop, g, reps=reps)
        bucketed_us += t_b
        per_slice_us += t_p
        est_full_us += t_b / batch * full[shape]
        rows.append({"bench": "ns", "arch": timing_cfg.name, "kind": "time",
                     "shape": f"{batch}x{shape[0]}x{shape[1]}",
                     "depth": depth,
                     "bucketed_us": round(t_b, 1),
                     "per_slice_loop_us": round(t_p, 1),
                     "speedup": round(t_p / t_b, 3)})
    rows.append({"bench": "ns", "arch": cfg.name, "kind": "time_total",
                 "depth": depth,
                 "bucketed_us_per_step": round(bucketed_us, 1),
                 "per_slice_us_per_step": round(per_slice_us, 1),
                 "bucketed_est_full_depth_us": round(est_full_us, 1),
                 "speedup": round(per_slice_us / bucketed_us, 3)})

    # ---- interpret-mode correctness of the fused iteration kernel
    x = jax.random.normal(key, (2, 128, 256), jnp.float32) * 0.05
    got = ns_iteration_fused(x, ref.NS_COEFFS, interpret=True)
    want = ref.ns_iteration_batched_ref(x, ref.NS_COEFFS)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append({"bench": "ns", "kind": "fused_kernel_interpret",
                 "shape": "2x128x256", "max_abs_err": err})
    return rows


# ------------------------------------------------- SPMD flop-ratio A/B
#
# The sharding-awareness regression guard: a small-mesh (8 emulated host
# devices) dry-run A/B of the EF21-Muon step with NS bucketing on vs
# off, per-device HLO FLOPs from the compiled modules. Without the
# ns_bucket_pspec constraints the bucket concat drops the per-leaf
# TP/zero-1 shardings and this ratio regresses hard (the 512-chip
# granite dry-run measured 1.137x; with the constraints it is < 1 —
# batch sharding is parallelism the per-leaf path never had). Runs in a
# subprocess so the 8-device XLA_FLAGS never leak into the caller.

NS_SPMD_RATIO_BOUND = 1.02
# staged / monolithic overlap-aware exposed-collective time (§8): the
# K-gather pipeline must expose strictly less collective time than the
# single blocking gather (measured ~0.8x on the 8-device mesh)
PIPELINE_EXPOSED_BOUND = 0.98

SPMD_AB_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

import sys
sys.path.insert(0, "src")
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.kernels import ref
from repro.kernels.ops import newton_schulz_batched
from repro.launch.hlo_analysis import overlap_roofline_terms
from repro.launch.hlo_cost import analyze
from repro.models.api import build_model
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_config("granite-3-2b").reduced()
model = build_model(cfg)
shape = ShapeSpec("t", "train", 32, 8)
rec = {}

def arm(mesh, n_workers, bucketing, wire_stages="auto"):
    tr = Trainer(model, TrainerConfig(
        n_workers=n_workers, beta=0.5, w2s="top10+natural",
        use_pallas=False, remat=False, zero1_lmo=True,
        ns_bucketing=bucketing, wire_stages=wire_stages), mesh=mesh)
    data = SyntheticLM(cfg, shape, n_workers=n_workers, seed=0)
    batch = data.batch_at(0)
    bshapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    step = tr.jit_step(bshapes)
    state = tr.init(jax.random.key(0))
    state = jax.device_put(state, tr.shardings(bshapes)[0])
    a = analyze(step.lower(state, batch, jnp.asarray(0.01, jnp.float32))
                .compile().as_text())
    state, aux = step(state, batch, 0.01)
    plan = tr.layer_plan()
    wire = plan.wire_layout(tr.opt.cfg.wire_dtype).total_nbytes
    a["n_stages"] = plan.stage_plan(
        mesh=mesh, wire_stages=wire_stages).n_stages if bucketing else 1
    a["t_exposed"] = overlap_roofline_terms(
        a["flops"], a["hbm_bytes"], a["coll_bytes"],
        a["coll_pairs"])["t_exposed_collective_s"]
    return a, state, wire

# mesh A (4 data x 2 model): per-device FLOP ratio + wire invariants.
# TP splits NS contractions here, so cross-arm equality is approximate
# (reduction order) — bitwise equality is asserted on mesh B below,
# where every slice stays whole per device.
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
a_on, st_on, wire = arm(mesh, 4, True)
a_off, st_off, _ = arm(mesh, 4, False)
# third arm: bucketing on, monolithic single gather (wire_stages=1) —
# the staged-pipeline A/B baseline for the exposed-collective ratio
a_mono, st_mono, _ = arm(mesh, 4, True, wire_stages=1)
rec["flops_on"] = a_on["flops"]
rec["flops_off"] = a_off["flops"]
rec["ns_flops_ratio"] = a_on["flops"] / a_off["flops"]
rec["n_stages_on"] = a_on["n_stages"]
rec["u8_count_on"] = a_on["u8_coll_count"]
rec["u8_count_off"] = a_off["u8_coll_count"]
rec["u8_count_mono"] = a_mono["u8_coll_count"]
rec["u8_bytes_on"] = a_on["u8_coll_bytes"]
rec["u8_bytes_off"] = a_off["u8_coll_bytes"]
rec["u8_bytes_mono"] = a_mono["u8_coll_bytes"]
rec["wire_bytes"] = wire
rec["t_exposed_staged"] = a_on["t_exposed"]
rec["t_exposed_mono"] = a_mono["t_exposed"]
rec["exposed_ratio"] = (a_on["t_exposed"] / a_mono["t_exposed"]
                        if a_mono["t_exposed"] else None)
rec["x_max_abs_diff_4x2"] = max(
    float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    for a, b in zip(jax.tree.leaves(st_on["x"]),
                    jax.tree.leaves(st_off["x"])))
# staged vs monolithic is a pure repartition: bit-equal even under TP
rec["bit_equal_staged_mono"] = all(jax.tree.leaves(jax.tree.map(
    lambda a, b: bool(jnp.all(a == b)), st_on["x"], st_mono["x"])))

# mesh B (8 data x 1 model): zero-1 + batch sharding only slice the
# batch/stack dims — no contraction is ever split, so bucketed == per-
# leaf stays BIT-equal on the jnp path even under real 8-device SPMD.
mesh1 = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
_, st_on1, _ = arm(mesh1, 8, True)
_, st_off1, _ = arm(mesh1, 8, False)
rec["bit_equal_8x1"] = all(jax.tree.leaves(jax.tree.map(
    lambda a, b: bool(jnp.all(a == b)), st_on1["x"], st_off1["x"])))

# shard_map around the fused Pallas iteration (interpret): the kernel
# runs on local [B/shards, m, n] sub-batches and matches the oracle.
g = jax.random.normal(jax.random.key(1), (8, 48, 80), jnp.float32) * 0.1
got = jax.jit(lambda x: newton_schulz_batched(
    x, steps=3, use_pallas=True, interpret=True, mesh=mesh,
    pspec=P("data", None, "model")))(g)
rec["shard_map_max_err"] = float(jnp.max(jnp.abs(
    got - ref.newton_schulz_batched_ref(g, steps=3))))
print(json.dumps(rec))
"""


def spmd_ab(timeout: int = 1800) -> dict:
    """Run the 8-host-device bucketing A/B subprocess; returns the
    record (per-device FLOPs both arms, ratio, wire invariants, 8x1
    bit-equality, shard_map kernel error)."""
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    root = os.path.join(os.path.dirname(__file__), "..")
    out = subprocess.run([sys.executable, "-c", SPMD_AB_SCRIPT],
                         capture_output=True, text=True, cwd=root, env=env,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"spmd_ab subprocess failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_spmd_ab() -> list[dict]:
    rec = spmd_ab()
    row = {"bench": "ns", "arch": "granite-3-2b-reduced", "kind": "spmd_ab",
           "mesh": "4x2+8x1 host", **rec}
    assert rec["ns_flops_ratio"] <= NS_SPMD_RATIO_BOUND, rec
    # staged wire invariant (§8): K u8 gathers in the staged arm, one in
    # the monolithic / per-leaf arms, bytes summing to the wire layout
    assert rec["n_stages_on"] > 1, rec
    assert rec["u8_count_on"] == rec["n_stages_on"], rec
    assert rec["u8_count_off"] == 1 and rec["u8_count_mono"] == 1, rec
    assert rec["u8_bytes_on"] == rec["u8_bytes_off"] \
        == rec["u8_bytes_mono"] == rec["wire_bytes"], rec
    # overlap-aware roofline: the staged arm exposes strictly less
    # collective time than the monolithic single-gather arm (a None
    # ratio means the mono arm measured as fully hidden — a parser/
    # model regression worth failing on)
    assert rec["exposed_ratio"] is not None \
        and rec["exposed_ratio"] <= PIPELINE_EXPOSED_BOUND, rec
    assert rec["bit_equal_staged_mono"], rec
    assert rec["bit_equal_8x1"], rec
    assert rec["x_max_abs_diff_4x2"] < 1e-6, rec
    assert rec["shard_map_max_err"] < 2e-3, rec
    return [row]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ns.json")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--spmd-ab", action="store_true",
                    help="also run the 8-device bucketing-on/off FLOP "
                         "ratio A/B (subprocess; the slow CI job's "
                         "regression guard)")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    if args.spmd_ab:
        rows += run_spmd_ab()
    for r in rows:
        print(json.dumps(r), flush=True)
    disp = next(r for r in rows if r["kind"] == "dispatch")
    assert 0 < disp["ns_calls_bucketed"] \
        <= disp["ns_steps"] * disp["n_buckets"]
    kerr = next(r for r in rows if r["kind"] == "fused_kernel_interpret")
    assert kerr["max_abs_err"] < 1e-4, kerr
    with open(args.out, "w") as f:
        json.dump({"bench": "ns_bench", "rows": rows}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
