"""Resync/recovery soak (DESIGN.md §13) — ``BENCH_resync.json``.

Two arms, one row each:

  ``soak``        the heterogeneous-quadratic EF21-Muon step with the
                  rejoin subsystem compiled in (R=4 replay ring) under a
                  deterministic absence schedule that exercises BOTH
                  recovery paths: short absences (lag <= R, replayed
                  from the ring) and one long absence (lag > R, full W
                  resync). Reports replay-vs-full counts, recovery
                  latency (rounds caught up per rejoin), the max
                  version lag, and the bit-equality of every worker's W
                  estimate against the server's at the end — the §13
                  invariant, measured not assumed.
  ``supervisor``  the host-side half: a supervised loop over the same
                  step with an injected stall longer than the step
                  timeout — reports retries, recovery wall latency, and
                  that the run completed.

The CI chaos-soak job complements this with the out-of-process arm
(``bernoulli(0.5)`` + stall + hard crash + ``--resume`` through the
train CLI); this module is the deterministic, committed trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.muon import EF21Muon, EF21MuonConfig, ParamMeta
from repro.dist.participation import Explicit
from repro.train.faults import parse_faults
from repro.train.supervisor import Supervisor, SupervisorConfig

N_W = 4
RING = 4


def _problem(dim=16, seed=0):
    key = jax.random.key(seed)
    Ts = jax.random.normal(key, (N_W, dim, dim))

    def gal(p, wb):
        t = Ts[jnp.int32(wb[0])]
        return 0.5 * jnp.sum((p - t) ** 2), (p - t)

    return (jnp.zeros((dim, dim)), ParamMeta("spectral", 1.0, 0), gal,
            jnp.arange(float(N_W)).reshape(N_W, 1))


def _absence_schedule(n_steps: int):
    """Deterministic mask table: worker 1 takes two short absences
    (2 and 3 rounds — both replayable at R=4) and worker 2 one long
    absence (6 rounds > R — full resync); everyone else stays."""
    masks = [[1] * N_W for _ in range(n_steps)]
    for s in range(3, 5):
        masks[s][1] = 0          # lag 2  -> replay
    for s in range(10, 13):
        masks[s][1] = 0          # lag 3  -> replay
    for s in range(16, 22):
        masks[s][2] = 0          # lag 6  -> full resync
    return Explicit(tuple(tuple(m) for m in masks))


def _soak_row(fast: bool) -> dict:
    n_steps = 30 if fast else 60
    params, metas, gal, batch = _problem()
    opt = EF21Muon(EF21MuonConfig(
        n_workers=N_W, beta=0.5, w2s="top10", s2w="natural",
        use_pallas=False, participation=_absence_schedule(n_steps),
        resync=RING))
    state = opt.init(jax.random.key(0), params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas)(s, gal, b, 0.05))
    replayed = full = 0
    lags, losses, recovery_lags = [], [], []
    prev_lag = 0
    for _ in range(n_steps):
        state, aux = step(state, batch)
        r, f = int(aux["resync_replayed"]), int(aux["resync_full"])
        lag = int(aux["version_lag_max"])
        if r or f:
            # rounds the rejoining worker was behind == its recovery
            # latency in steps (the replay/full copy closes it at once)
            recovery_lags.append(prev_lag)
        replayed += r
        full += f
        lags.append(lag)
        prev_lag = lag
        losses.append(float(aux["loss"]))
    w = np.asarray(state["w"])
    bit_equal = all(
        np.array_equal(np.asarray(state["w_w"][j]), w) for j in range(N_W))
    return {
        "bench": "resync", "arm": "soak", "steps": n_steps,
        "ring_depth": RING, "replayed": replayed, "full": full,
        "max_version_lag": int(max(lags)),
        "mean_recovery_latency_steps": round(
            float(np.mean(recovery_lags)), 3) if recovery_lags else 0.0,
        "final_loss": round(losses[-1], 4),
        "loss_descending": bool(losses[-1] < losses[0]),
        "w_estimates_bit_equal": bool(bit_equal),
    }


def _supervisor_row(fast: bool) -> dict:
    params, metas, gal, batch = _problem()
    opt = EF21Muon(EF21MuonConfig(n_workers=N_W, beta=0.5, w2s="top10",
                                  use_pallas=False))
    state = opt.init(jax.random.key(0), params, metas)
    step = jax.jit(lambda s, b: opt.make_step(metas)(s, gal, b, 0.05))
    state, _ = step(state, batch)   # compile outside the watched region
    n_steps = 6 if fast else 12
    stall_at = 2
    faults = parse_faults(f"stall:w=0:steps={stall_at}:ms=60000", N_W)
    sup = Supervisor(SupervisorConfig(step_timeout_s=2.0, max_retries=2,
                                      backoff_base_s=0.01))
    t0 = time.time()
    t_recover = 0.0
    for i in range(n_steps):
        t_s = time.time()
        result, _, _ = sup.run_step(step, state, batch, step=i,
                                    faults=faults)
        state, _ = result
        if i == stall_at:
            t_recover = time.time() - t_s
    return {
        "bench": "resync", "arm": "supervisor", "steps": n_steps,
        "retries": sup.retries, "reloads": sup.reloads,
        "stalled_step_recovery_s": round(t_recover, 2),
        "wall_s": round(time.time() - t0, 2),
        "completed": True,
    }


def run(fast: bool = False):
    return [_soak_row(fast), _supervisor_row(fast)]
