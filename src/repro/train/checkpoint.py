"""Flat-npz checkpointing for arbitrary pytrees (params / full EF21 state).

Paths are '/'-joined tree keys; dtypes and the tree structure round-trip
exactly. Works for resuming training (examples) and for exporting served
weights. Multi-host note: on a real slice each host saves its addressable
shards under a host suffix; on CPU there is one host, so this degenerates
to a single file.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

from repro.obs.trace import span


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        out[prefix + "__none__"] = np.zeros((0,))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any, step: int | None = None) -> None:
    with span("ckpt/save"):
        flat = _flatten(tree)
        if step is not None:
            flat["__step__"] = np.asarray(step)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.savez(path, **flat)


def load_checkpoint(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with span("ckpt/load"):
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
        step = int(flat.pop("__step__")) if "__step__" in flat else None

        def rebuild(sub: Any, prefix: str = ""):
            if isinstance(sub, dict):
                return {k: rebuild(v, f"{prefix}{k}/") for k, v in sub.items()}
            if sub is None:
                return None
            arr = flat[prefix.rstrip("/")]
            return jax.numpy.asarray(arr).astype(sub.dtype)

        return rebuild(like), step
