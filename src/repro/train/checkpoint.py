"""Flat-npz checkpointing for arbitrary pytrees (params / full EF21 state).

Paths are '/'-joined tree keys; dtypes and the tree structure round-trip
exactly. Works for resuming training (examples) and for exporting served
weights. Multi-host note: on a real slice each host saves its addressable
shards under a host suffix; on CPU there is one host, so this degenerates
to a single file.

Robustness (DESIGN.md §11): a checkpoint is only useful if the run that
reads it back can trust it after a mid-write crash or disk corruption.

  * ``save_checkpoint`` writes to a temp file in the target directory and
    publishes with ``os.replace`` — the atomic-rename pattern, so the
    target path only ever holds a complete file. A pre-existing
    checkpoint is rotated to ``<path>.prev`` first (same-directory
    rename, also atomic), keeping exactly one last-good generation; a
    legacy bare-path archive (pre-``.npz`` runs) counts as that previous
    generation and is rotated the same way, so it can no longer shadow
    freshly saved files on load. After the publish the parent directory
    is fsynced — the rename itself isn't durable on power loss
    otherwise.
  * The archive embeds a ``__manifest__`` JSON entry with a per-array
    CRC32 + shape + dtype; ``load_checkpoint`` re-hashes every array and
    refuses silently-corrupted data, not just truncated zips.
  * On any load failure (missing entry, bad zip, checksum mismatch)
    ``load_checkpoint`` falls back to ``<path>.prev`` with a warning
    before giving up — a torn newest generation costs one checkpoint
    interval, not the run.

Pre-manifest checkpoints (older runs) still load: the checksum pass is
skipped when the archive has no ``__manifest__`` entry.
"""
from __future__ import annotations

import json
import os
import warnings
import zlib
from typing import Any

import jax
import numpy as np

from repro.obs.trace import span

_MANIFEST_KEY = "__manifest__"
_STEP_KEY = "__step__"


class CheckpointError(RuntimeError):
    """A checkpoint file is unreadable or fails its checksum manifest."""


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        out[prefix + "__none__"] = np.zeros((0,))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _npz_path(path: str) -> str:
    # np.savez historically appended ".npz" to bare paths; keep that
    # contract so existing --checkpoint values resolve to the same file
    return path if path.endswith(".npz") else path + ".npz"


def _checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _build_manifest(flat: dict[str, np.ndarray]) -> str:
    return json.dumps({
        k: {"crc32": _checksum(v), "shape": list(v.shape),
            "dtype": str(v.dtype)}
        for k, v in flat.items()})


def save_checkpoint(path: str, tree: Any, step: int | None = None) -> None:
    with span("ckpt/save"):
        flat = _flatten(tree)
        if step is not None:
            flat[_STEP_KEY] = np.asarray(step)
        manifest = _build_manifest(flat)
        flat[_MANIFEST_KEY] = np.frombuffer(
            manifest.encode(), dtype=np.uint8).copy()
        final = _npz_path(path)
        os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
        # temp file in the same directory => os.replace stays a same-
        # filesystem atomic rename; a crash mid-save leaves the previous
        # generation at `final` untouched
        tmp = final + ".tmp"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
            if path != final and os.path.exists(path) \
                    and not os.path.exists(final):
                # legacy pre-".npz" archive at the bare path: left in
                # place it would shadow `final` on every future load
                # (load_checkpoint prefers an existing bare path), so
                # rotate it to the last-good slot like any other
                # previous generation
                os.replace(path, final + ".prev")
            elif os.path.exists(final):
                os.replace(final, final + ".prev")
            os.replace(tmp, final)
            _fsync_dir(os.path.dirname(final) or ".")
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)


def _fsync_dir(dirname: str) -> None:
    # os.replace makes the file content durable but the *rename* lives
    # in the directory; without this a power loss can resurrect the old
    # directory entry. Best-effort: not all filesystems allow dir fds.
    try:
        fd = os.open(dirname, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _read_verified(path: str) -> dict[str, np.ndarray]:
    """Load + checksum-verify one npz; raises CheckpointError."""
    try:
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
    except Exception as e:  # bad zip / truncation / unreadable entry
        raise CheckpointError(f"{path}: unreadable checkpoint: {e}") from e
    raw = flat.pop(_MANIFEST_KEY, None)
    if raw is None:
        return flat    # pre-manifest checkpoint: nothing to verify
    try:
        manifest = json.loads(raw.tobytes().decode())
    except Exception as e:
        raise CheckpointError(f"{path}: corrupt manifest: {e}") from e
    if set(manifest) != set(flat):
        raise CheckpointError(
            f"{path}: manifest/content key mismatch: "
            f"{sorted(set(manifest) ^ set(flat))[:4]}")
    for k, ent in manifest.items():
        if _checksum(flat[k]) != ent["crc32"]:
            raise CheckpointError(f"{path}: checksum mismatch on {k!r}")
    return flat


def load_checkpoint(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). Falls back to ``<path>.prev`` if the newest
    generation is torn/corrupt; raises CheckpointError if both fail."""
    with span("ckpt/load"):
        final = _npz_path(path) if not os.path.exists(path) else path
        try:
            flat = _read_verified(final)
        except CheckpointError as e:
            prev = final + ".prev"
            if not os.path.exists(prev):
                raise
            warnings.warn(f"{e}; falling back to last-good {prev}",
                          RuntimeWarning, stacklevel=2)
            flat = _read_verified(prev)
        step = int(flat.pop(_STEP_KEY)) if _STEP_KEY in flat else None

        def rebuild(sub: Any, prefix: str = ""):
            if isinstance(sub, dict):
                return {k: rebuild(v, f"{prefix}{k}/") for k, v in sub.items()}
            if sub is None:
                return None
            arr = flat[prefix.rstrip("/")]
            return jax.numpy.asarray(arr).astype(sub.dtype)

        return rebuild(like), step
