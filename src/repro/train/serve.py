"""Serving driver: batched prefill + greedy/temperature decode loop.

``Server`` wraps a model with jitted prefill/decode_step functions and a
simple continuous-batching-style ``generate`` that runs prefill once and
then steps the decoder; this is the engine behind
examples/serve_batched.py and the decode dry-run entry points.

When a mesh is provided, all placement comes from ``repro.dist``: params
follow ``param_pspec`` (TP/expert-parallel), the KV/recurrent cache
follows ``serve_pspecs`` (batch over ``data``, sequence over ``model``)
and inputs follow ``batch_pspec`` — ``generate`` places its operands
before the first jitted call, so the same driver runs single-host and
SPMD unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.sharding import (batch_pspec, param_pspecs, serve_pspecs,
                                 to_shardings)


@dataclass
class Server:
    model: Any
    mesh: Mesh | None = None

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._metas = None
        if self.mesh is not None:
            from repro.models.api import abstract_params
            _, self._metas = abstract_params(self.model)

    # The serve_step the decode-shape dry-runs lower: ONE token against a
    # seq_len cache.
    def serve_step_fn(self):
        return self.model.decode_step

    # ------------------------------------------------------------ placement
    def shardings(self, params: Any, batch: dict, cache: Any,
                  cache_alt: Any = None):
        """(param, batch, cache) NamedShardings from the dist rules.
        ``cache_alt`` (the cache spec at another batch size) makes the
        batch-dim detection exact — see ``serve_pspecs``."""
        assert self.mesh is not None
        bsz = next(iter(batch.values())).shape[0]
        return (to_shardings(param_pspecs(params, self._metas, self.mesh),
                             self.mesh),
                to_shardings(batch_pspec(batch, self.mesh, "prefill"),
                             self.mesh),
                to_shardings(serve_pspecs(cache, bsz, self.mesh,
                                          cache_alt=cache_alt), self.mesh))

    def _placed(self, params, p_sh):
        # one-slot placed-params cache: a long-lived server calls generate
        # repeatedly with the same weights — don't re-scatter them per
        # call. The entry keeps strong refs to the source leaves and
        # compares by identity (JAX arrays are immutable, so any weight
        # swap replaces leaves and misses; the kept refs mean CPython can
        # never recycle their ids while the entry is live).
        leaves = jax.tree.leaves(params)
        cached = getattr(self, "_placed_params", None)
        if (cached is None or len(cached[0]) != len(leaves)
                or any(a is not b for a, b in zip(cached[0], leaves))):
            cached = (leaves, jax.device_put(params, p_sh))
            self._placed_params = cached
        return cached[1]

    # -------------------------------------------------------------- decoding
    def generate(self, params, batch: dict, max_new: int,
                 temperature: float = 0.0, key: jax.Array | None = None):
        """Prefill on ``batch`` then decode ``max_new`` tokens."""
        bsz = next(iter(batch.values())).shape[0]
        prompt_len = (batch["tokens"].shape[1] if "tokens" in batch
                      else batch["embeds"].shape[1])
        total = prompt_len + max_new
        if self.mesh is not None:
            # per-request-shape placement memo: shardings are a function
            # of (bsz, prompt_len, total, modality) only, and the jitted cache init
            # builds the cache directly under its target sharding — the
            # cache is the serving memory bottleneck, so it must never be
            # materialised unsharded on one device first. Bounded like the
            # optimizer's plan cache; real servers see a few shapes.
            memo = getattr(self, "_placement_memo", None)
            if memo is None:
                memo = self._placement_memo = {}
            mkey = (bsz, prompt_len, total, tuple(sorted(batch)))
            if mkey not in memo:
                if len(memo) >= 8:
                    memo.clear()
                p_sh, b_sh, c_sh = self.shardings(
                    params, batch, self.model.cache_spec(bsz, total),
                    cache_alt=self.model.cache_spec(bsz + 1, total))
                memo[mkey] = (p_sh, b_sh, jax.jit(
                    partial(self.model.init_cache, bsz, total),
                    out_shardings=c_sh))
            p_sh, b_sh, init_cache = memo[mkey]
            params = self._placed(params, p_sh)
            batch = jax.device_put(batch, b_sh)
            cache = init_cache()
        else:
            cache = self.model.init_cache(bsz, total)
        logits, cache = self._prefill(params, batch, cache)
        toks = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(max_new):
            toks.append(tok)
            logits, cache = self._decode(
                params, {"token": tok,
                         "t": jnp.asarray(prompt_len + i, jnp.int32)},
                cache)
            key = jax.random.fold_in(key, i) if key is not None else None
            tok = self._sample(logits, temperature, key, i + 1)
        if not toks:   # max_new=0: prefill-only warmup
            return jnp.zeros((bsz, 0), jnp.int32)
        return jnp.concatenate(toks, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, i), logits / temperature,
            axis=-1)[:, None].astype(jnp.int32)
