"""Serving driver: batched prefill + greedy/temperature decode loop.

``Server`` wraps a model with jitted prefill/decode_step functions (with
mesh shardings when provided) and a simple continuous-batching-style
``generate`` that runs prefill once and then steps the decoder; this is
the engine behind examples/serve_batched.py and the decode dry-run entry
points.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import batch_pspec, param_pspec, serve_pspecs, \
    to_shardings


@dataclass
class Server:
    model: Any
    mesh: Mesh | None = None

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    # The serve_step the decode-shape dry-runs lower: ONE token against a
    # seq_len cache.
    def serve_step_fn(self):
        return self.model.decode_step

    def generate(self, params, batch: dict, max_new: int,
                 temperature: float = 0.0, key: jax.Array | None = None):
        """Prefill on ``batch`` then decode ``max_new`` tokens."""
        bsz = next(iter(batch.values())).shape[0]
        prompt_len = (batch["tokens"].shape[1] if "tokens" in batch
                      else batch["embeds"].shape[1])
        cache = self.model.init_cache(bsz, prompt_len + max_new)
        logits, cache = self._prefill(params, batch, cache)
        toks = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(max_new):
            toks.append(tok)
            logits, cache = self._decode(
                params, {"token": tok,
                         "t": jnp.asarray(prompt_len + i, jnp.int32)},
                cache)
            key = jax.random.fold_in(key, i) if key is not None else None
            tok = self._sample(logits, temperature, key, i + 1)
        return jnp.concatenate(toks, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, i), logits / temperature,
            axis=-1)[:, None].astype(jnp.int32)
