"""Distributed EF21-Muon trainer.

Wires together: model (loss fn), EF21Muon optimizer (layer-wise LMO +
bidirectional compressed error feedback), the mesh partition rules, and
the payload resharding hook that turns the w2s "send" into an all-gather
of *compressed payloads only* across the worker axis.

The dataflow per step (DESIGN.md §5):

  1. (EF21-P) S = C_P(X - W) on the server; S rides the s2w wire leg
     (packed u8 buffer, broadcast over the worker axis, §9) and both
     ends advance W from the same wire bytes: W += unpack(S)
  2. per-worker grads at W via vmap(grad, in_axes=(None, 0))  — no
     cross-worker collectives are induced: worker computations are
     independent by construction.
  3. per-worker momentum + EF21 compress: R_j = C_D(M_j - G_j); G_j += R_j
  4. payloads packed into one contiguous uint8 buffer per worker
     (repro.wire), then resharded to replicated == ONE fused all-gather
     of exactly the accounted payload bytes over the worker axis (the
     *only* cross-worker communication).
  5. replicated server: G += mean_j decompress(unpack(R_j));
     X = LMO_B(X, t)(G).

Used both for real (CPU-scale) training in examples/benchmarks and for
the multi-pod dry-run (ShapeDtypeStruct in, .lower().compile() out).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.muon import EF21Muon, EF21MuonConfig
from repro.dist.sharding import (batch_pspec, state_pspecs, to_shardings,
                                 worker_axis_for)
from repro.obs.trace import phase_span


@dataclass
class TrainerConfig:
    n_workers: int = 1
    beta: float = 0.1
    w2s: str = "identity"
    s2w: str = "identity"
    radius: float = 0.02
    fsdp: bool = False
    remat: bool = True
    ns_steps: int = 5
    use_pallas: Any = "auto"
    zero1_lmo: bool = False   # beyond-paper: layer-parallel LMO sharding
    wire_pack: bool = True    # fused uint8 payload buffer (repro.wire)
    ns_bucketing: bool = True  # shape-bucketed batched NS LMOs (§7)
    wire_stages: Any = "auto"  # staged wire pipeline (§8): "auto" = one
                               # stage per NS bucket + eager chunk; 1 =
                               # the monolithic single-gather A/B arm
    wire_pack_s2w: Any = "auto"  # s2w wire leg (§9): pack the EF21-P
                                 # model-update broadcast; "auto" follows
                                 # wire_pack, False = unpacked A/B arm
    metrics: bool = False      # in-graph MetricSet in aux["metrics"]
                               # (§10); off arm lowers identically
    trace_spans: bool = False  # named-scope the phases + wire stages
                               # (§10) for xprof; off = no HLO change
    participation: Any = "full"  # elastic worker participation (§11):
                                 # "full" | "bernoulli(p)" |
                                 # "round_robin(k)" | Explicit masks;
                                 # "full" is the bit-equal arm
    participation_seed: int = 0  # seeds bernoulli participation
    nonfinite_guard: Any = "auto"  # payload finiteness guard (§11):
                                   # "auto" = on iff participation is
                                   # elastic or faults are declared
    faults: Any = None         # train.faults.FaultPlan — seeded chaos
                               # schedule (drops / NaN grads / wire bit
                               # flips) injected inside the step (§11);
                               # forces the guard on
    resync: Any = None         # desynchronized-worker rejoin (§13):
                               # None/0 compiles the subsystem out
                               # (lowering-identical to the pre-§13
                               # step); an int R >= 1 keeps per-worker W
                               # estimates + an R-deep replay ring of
                               # packed s2w rounds. Requires a
                               # compressing s2w leg
    donate: bool = False       # donate the optimizer state to the jitted
                               # step (donate_argnums=(0,)): X / EF21
                               # error / momentum buffers are updated
                               # in place instead of double-buffered.
                               # Donation lives at the jit boundary, so
                               # this is applied in ``jit_step`` — the
                               # §12 donation-audit rule checks the
                               # compiled input_output_alias against it


class Trainer:
    def __init__(self, model, tcfg: TrainerConfig, mesh: Mesh | None = None):
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        guard = tcfg.nonfinite_guard
        if guard == "auto":
            # chaos implies the guard: any declared faults or an elastic
            # schedule turn it on; the plain arm stays bit-equal (§11)
            guard = tcfg.faults is not None or tcfg.participation != "full"
        self.opt = EF21Muon(EF21MuonConfig(
            n_workers=tcfg.n_workers, beta=tcfg.beta, w2s=tcfg.w2s,
            s2w=tcfg.s2w, ns_steps=tcfg.ns_steps,
            use_pallas=tcfg.use_pallas, wire_pack=tcfg.wire_pack,
            ns_bucketing=tcfg.ns_bucketing, wire_stages=tcfg.wire_stages,
            wire_pack_s2w=tcfg.wire_pack_s2w, metrics=tcfg.metrics,
            trace_spans=tcfg.trace_spans,
            participation=tcfg.participation,
            participation_seed=tcfg.participation_seed,
            nonfinite_guard=bool(guard), resync=tcfg.resync))
        # metas are static: build once from the model's abstract init
        from repro.models.api import abstract_params
        self._params_shapes, self.metas = abstract_params(model)

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        params, _ = self.model.init(key)
        state = self.opt.init(jax.random.fold_in(key, 1), params,
                              self.metas)
        if self.tcfg.donate:
            # XLA rejects donating one buffer twice, and tied leaves
            # (e.g. shared embed/unembed) are the same array at init.
            # Copy repeats into their own buffers — every later step
            # returns distinct output buffers anyway, so this only
            # mirrors the steady state.
            seen: set[int] = set()

            def _dedup(x):
                if id(x) in seen:
                    return jnp.copy(x)
                seen.add(id(x))
                return x

            state = jax.tree.map(_dedup, state)
        return state

    def state_shapes(self) -> Any:
        """Abstract optimizer state (dry-run input)."""
        return jax.eval_shape(
            lambda k, p: self.opt.init(k, p, self.metas),
            jax.random.key(0), self._params_shapes)

    def layer_plan(self):
        """The optimizer's LayerPlan for this model — the one source of
        truth for per-leaf compressors and w2s wire bytes (Table 2)."""
        return self.opt.plan(self._params_shapes, self.metas)

    # --------------------------------------------------------------- specs
    def shardings(self, batch_shapes: Any):
        assert self.mesh is not None
        st = self.state_shapes()
        sspec = state_pspecs(st, self._params_shapes, self.metas, self.mesh,
                             fsdp=self.tcfg.fsdp,
                             zero1_lmo=self.tcfg.zero1_lmo)
        bspec = batch_pspec(batch_shapes, self.mesh, "train")
        return (to_shardings(sspec, self.mesh),
                to_shardings(bspec, self.mesh))

    # ---------------------------------------------------------------- step
    def _grad_and_loss(self, params, batch_slice):
        loss, grads = jax.value_and_grad(
            partial(self.model.loss, remat=self.tcfg.remat))(
                params, batch_slice)
        return loss, grads

    def make_step(self) -> Callable:
        """Returns step(state, batch, t) -> (state, aux). jit outside."""
        if self.mesh is not None:
            waxis = worker_axis_for(self.mesh)
            wn = self.mesh.shape[waxis]
            sharded = NamedSharding(self.mesh, P(waxis))
            replicated = NamedSharding(self.mesh, P())

            def reshard(payloads):
                # w2s communication: with wire packing this receives ONE
                # [n_workers, nbytes] uint8 buffer per call; pin it to
                # the worker axis, then replicate == a fused all-gather
                # of compressed payload bytes over exactly the slow
                # links (DESIGN.md §3, §6). The staged wire pipeline
                # (§8) invokes this hook once per stage sub-buffer —
                # K independent payload all-gathers whose bytes sum to
                # WireLayout.total_nbytes — and the monolithic arm
                # (wire_stages=1) exactly once. The tree.map keeps the
                # unpacked (wire_pack=False) per-leaf path working.
                def one(x):
                    if x.ndim and x.shape[0] % wn == 0:
                        x = jax.lax.with_sharding_constraint(x, sharded)
                    return jax.lax.with_sharding_constraint(x, replicated)

                with phase_span("trainer/reshard_payloads",
                                self.tcfg.trace_spans):
                    return jax.tree.map(one, payloads)

            def broadcast_updates(bufs):
                # s2w communication (DESIGN.md §9): the optimizer hands
                # over the tiled [n_workers, nbytes] uint8 model-update
                # buffer — every worker-domain's copy of the server's
                # single compressed message. Pinning to the worker axis
                # then replicating lowers to ONE u8 all-gather per
                # stage sub-buffer whose per-device operand bytes are
                # exactly the s2w WireLayout account: the per-link cost
                # of the broadcast, measured by the same collective the
                # w2s leg uses, so the SPMD byte invariant becomes a
                # two-direction statement.
                with phase_span("trainer/broadcast_updates",
                                self.tcfg.trace_spans):
                    return reshard(bufs)
        else:
            reshard = None            # single-process: no collective,
            broadcast_updates = None  # no wire pack in either direction

        # mesh/fsdp make the bucketed NS dispatch sharding-aware (the
        # bucket stacks carry their ns_bucket_pspec instead of dropping
        # the per-leaf TP/zero-1 shardings at the concat)
        opt_step = self.opt.make_step(self.metas, reshard_payloads=reshard,
                                      mesh=self.mesh, fsdp=self.tcfg.fsdp,
                                      reshard_updates=broadcast_updates,
                                      faults=self.tcfg.faults)

        def step(state, batch, t):
            return opt_step(state, self._grad_and_loss, batch, t)

        return step

    def jit_step(self, batch_shapes: Any):
        """Jitted step with explicit in/out shardings (and the entry point
        the dry-run lowers). With ``tcfg.donate`` the optimizer state
        argument is donated: state in and state out share shardings (and
        matching avals leaf-for-leaf), so XLA aliases every state buffer
        instead of double-buffering the largest arrays in the program —
        callers must not reuse the input state after the call."""
        step = self.make_step()
        donate = (0,) if self.tcfg.donate else ()
        if self.mesh is None:
            return jax.jit(step, donate_argnums=donate)
        st_sh, b_sh = self.shardings(batch_shapes)
        return jax.jit(step, in_shardings=(st_sh, b_sh, None),
                       out_shardings=(st_sh, None),
                       donate_argnums=donate)

    def wire_budget(self):
        """The resolved :class:`repro.core.muon.WireBudget` of this
        trainer's step — the exact u8 collective population the §12
        wire rules check the compiled HLO against."""
        return self.opt.wire_budget(
            self._params_shapes, self.metas, mesh=self.mesh,
            fsdp=self.tcfg.fsdp, distributed=self.mesh is not None)
