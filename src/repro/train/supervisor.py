"""Supervised, crash-recoverable training loop (DESIGN.md §13).

The jitted step is pure — state in, state out — which makes host-level
recovery simple: any failed or wedged step attempt can be re-dispatched
from the last state the supervisor still holds, and a process crash can
be resumed from the last-good atomic checkpoint (``train/checkpoint.py``)
with no replayed side effects. The ``Supervisor`` wraps one step
invocation in exactly that contract:

  * **timeout/watchdog** — each attempt runs in a daemon watcher thread
    with a deadline. A wedged attempt (e.g. a ``stall`` fault, a hung
    collective) is *abandoned*: Python threads cannot be killed, so the
    supervisor orphans the thread (daemonic — it dies with the process)
    and dispatches the retry on a fresh one. This is only sound because
    the step is functional — the abandoned attempt's result, if it ever
    lands, is dropped on the floor.
  * **bounded retry with exponential backoff** — up to ``max_retries``
    re-dispatches per step, sleeping ``backoff_base_s * 2**attempt``
    (capped at ``backoff_max_s``) between attempts.
  * **reload on exception** — a raising attempt first retries from the
    in-memory state; if a checkpoint path is configured the final
    attempt(s) reload the last-good generation and continue from its
    step, trading up to ``checkpoint_every`` steps of progress for a
    live run.
  * **recovery telemetry** — every timeout / retry / reload / resume /
    periodic checkpoint emits a ``recovery`` record through the §10
    sink, and ``retries`` feeds the ``supervisor/retries`` metric.

Retries interact with host-side fault clauses deliberately: the stall
sleep (``FaultPlan.host_stall``) runs *inside* the watched call and only
on attempt 0, so a ``stall:...:ms=N`` with N above the step timeout
exercises the full timeout -> abandon -> clean-retry path.

The supervisor requires ``donate=False`` stepping: a donated input
buffer is invalidated even when the step fails, which would destroy the
very state a retry needs (the train CLI enforces this).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

from repro.train.checkpoint import load_checkpoint, save_checkpoint


class SupervisorError(RuntimeError):
    """A step failed every retry (and reload, when configured)."""


class StepTimeout(TimeoutError):
    """A watched step attempt exceeded ``step_timeout_s``."""


@dataclass(frozen=True)
class SupervisorConfig:
    step_timeout_s: float | None = None   # None = no watchdog
    max_retries: int = 2                  # re-dispatches per step
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    checkpoint_path: str | None = None
    checkpoint_every: int = 0             # 0 = only on demand

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.step_timeout_s is not None and self.step_timeout_s <= 0:
            raise ValueError("step_timeout_s must be positive")


class Supervisor:
    """Drives ``run_step`` attempts per the config above.

    ``writer`` is an optional ``obs.sink.MetricsWriter``; recovery events
    are dropped silently when absent so the supervisor composes with
    metrics-off runs.
    """

    def __init__(self, cfg: SupervisorConfig, writer: Any = None,
                 state_like: Any = None):
        self.cfg = cfg
        self.writer = writer
        self.retries = 0          # total re-dispatches this run
        self.reloads = 0          # checkpoint reloads this run
        self._state_like = state_like
        self._last_reload_step = -1

    # ------------------------------------------------------------ events
    def _event(self, event: str, step: int, attempt: int, **extra) -> None:
        if self.writer is not None:
            self.writer.write("recovery", step=int(step), event=event,
                              attempt=int(attempt), **extra)

    # ----------------------------------------------------------- attempt
    def _attempt(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` under the watchdog. The watcher is a *daemon*
        thread (a ThreadPoolExecutor would be joined at interpreter
        exit, so one wedged attempt could hang process shutdown); on
        timeout the thread is simply orphaned — sound because the step
        is functional and its late result, if any, is discarded."""
        if self.cfg.step_timeout_s is None:
            return fn()
        box: dict[str, Any] = {}
        done = threading.Event()

        def runner():
            try:
                box["result"] = fn()
            except BaseException as e:
                box["error"] = e
            finally:
                done.set()

        threading.Thread(target=runner, daemon=True,
                         name="supervised-step").start()
        if not done.wait(self.cfg.step_timeout_s):
            raise StepTimeout(
                f"attempt exceeded {self.cfg.step_timeout_s}s")
        if "error" in box:
            raise box["error"]
        return box["result"]

    def _backoff(self, attempt: int) -> None:
        time.sleep(min(self.cfg.backoff_base_s * (2 ** attempt),
                       self.cfg.backoff_max_s))

    # ---------------------------------------------------------- stepping
    def run_step(self, step_fn: Callable[..., Any], state: Any, *args,
                 step: int = -1, faults: Any = None) -> Any:
        """One supervised step: ``step_fn(state, *args)`` with timeout,
        retry, and (when configured) checkpoint-reload recovery.

        Returns ``(result, resumed_state, resumed_step)``. Normally
        ``result = step_fn(state, *args)`` and the other two are None.
        When every retry raised and a checkpoint is configured, the
        last-good generation is reloaded instead of raising: ``result``
        is None and the caller must install ``resumed_state`` and rewind
        its loop counter to ``resumed_step`` (the step to execute next)
        — re-stepping there picks the *correct* batch/schedule for that
        step, which is why the reload is not re-run in here. A second
        reload without forward progress past the first raises
        ``SupervisorError`` (a deterministic failure would otherwise
        reload forever). Host-side stall faults are applied inside the
        watched call, attempt 0 only. Raises ``SupervisorError`` when
        every recovery avenue is exhausted.
        """
        last_exc: BaseException | None = None
        for attempt in range(self.cfg.max_retries + 1):
            def call(attempt=attempt):
                if faults is not None:
                    faults.host_stall(step, attempt)
                return step_fn(state, *args)
            try:
                return self._attempt(call), None, None
            except StepTimeout as e:
                last_exc = e
                self._event("timeout", step, attempt)
            except Exception as e:
                last_exc = e
                self._event("retry", step, attempt, error=repr(e))
            if attempt < self.cfg.max_retries:
                self.retries += 1
                self._backoff(attempt)
        # retries exhausted: reload the last-good checkpoint if we can,
        # bounded to one reload per unit of forward progress
        if (self.cfg.checkpoint_path and self._state_like is not None
                and step > self._last_reload_step):
            try:
                ck_state, ck_step = load_checkpoint(
                    self.cfg.checkpoint_path, self._state_like)
            except Exception as e:
                last_exc = e
            else:
                self.reloads += 1
                self._last_reload_step = step
                resumed = ck_step if ck_step is not None else 0
                self._event("reload", step, self.cfg.max_retries,
                            resumed_step=resumed)
                return None, ck_state, resumed
        self._event("gave_up", step, self.cfg.max_retries)
        raise SupervisorError(
            f"step {step} failed after {self.cfg.max_retries + 1} "
            f"attempt(s)") from last_exc

    # ------------------------------------------------------- checkpoints
    def maybe_checkpoint(self, state: Any, step: int,
                         force: bool = False) -> bool:
        """Save the periodic last-good generation after completing
        ``step``; returns True when a checkpoint was written. The stored
        step is ``step + 1`` — the next step to execute — matching the
        train CLI's end-of-run convention, so a ``--resume`` (or a
        ``run_step`` reload) continues without re-running the step the
        checkpoint already contains."""
        every = self.cfg.checkpoint_every
        due = force or (every > 0 and step >= 0 and (step + 1) % every == 0)
        if not due or not self.cfg.checkpoint_path:
            return False
        save_checkpoint(self.cfg.checkpoint_path, state, step=step + 1)
        self._event("checkpoint", step, 0)
        return True

    def __enter__(self) -> "Supervisor":
        return self

    def __exit__(self, *exc) -> None:
        pass
