from .checkpoint import load_checkpoint, save_checkpoint
from .trainer import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "save_checkpoint", "load_checkpoint"]
