"""Deterministic fault injection for chaos runs (DESIGN.md §11).

A ``FaultPlan`` is a *seeded, declared* schedule of production failure
modes, injected inside the jitted step so a single A/B switch proves the
elastic-participation machinery end-to-end:

  ``drop``       worker j is absent for steps [start, stop) — ANDed into
                 the participation mask (``drop_mask``), so its EF21
                 error/momentum state freezes exactly like a scheduled
                 absence;
  ``nan``/``inf`` worker j's gradient for one (seeded-chosen) parameter
                 leaf is poisoned with NaN/Inf for steps [start, stop) —
                 the poison flows through momentum into the payload,
                 where the optimizer's non-finite guard demotes the
                 worker for the step;
  ``flip``       XOR a seeded set of byte positions of the gathered w2s
                 u8 wire buffer for steps [start, stop) — a torn/corrupt
                 wire payload. Bit flips that produce NaN/Inf floats are
                 caught by the guard; flips that decode to finite garbage
                 are absorbed by the EF21 feedback loop (that is the
                 claim the chaos tests pin).

Everything is static except the step comparison: fault sites (leaf
choice, byte positions, XOR masks) are drawn once from a
``numpy.random.Generator(seed)`` at plan-build time, and each injection
lowers to a ``jnp.where(step_in_range, faulty, clean)`` — the compiled
program is identical across steps and the schedule is exactly
reproducible (and resume-stable).

Two *host-side* clauses (DESIGN.md §13) drive the supervisor instead of
the jitted step — they never enter the graph:

  ``stall``      worker j's step is delayed by N milliseconds for steps
                 [start, stop) (``host_stall`` sleeps before dispatch);
                 exercises the supervisor's per-step timeout + retry
                 path. Retries skip the sleep, so a stalled step
                 recovers on attempt 1.
  ``crash``      hard ``os._exit`` at one step (``host_crash``) —
                 simulated power loss for crash/resume testing. Only
                 fires on a run that started from step 0, so the
                 ``--resume`` run sails past the crash step.

CLI grammar (``parse_faults``), comma-separated clauses:

    drop:w=1:steps=5-10          worker 1 absent for steps 5..9
    nan:w=0:steps=7              NaN gradient leaf on worker 0 at step 7
    inf:w=2:steps=3-6            Inf gradient leaf, worker 2, steps 3..5
    flip:steps=4:bits=8          8 flipped wire bytes at step 4
    stall:w=1:steps=5-7:ms=500   worker 1 stalls 500 ms at steps 5..6
    crash:step=9                 process hard-exits at step 9 (fresh
                                 runs only)

``steps=a-b`` is the half-open range [a, b); ``steps=a`` means [a, a+1).
"""
from __future__ import annotations

import os
import re
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

_CLAUSE_RE = re.compile(
    r"^(drop|nan|inf|flip|stall|crash)((?::[a-z_]+=[0-9-]+)*)$")

#: exit status of a ``crash:step=s`` fault — distinct from generic
#: failures so the soak harness can assert the crash actually fired.
CRASH_EXIT = 43


@dataclass(frozen=True)
class GradFault:
    worker: int
    start: int
    stop: int
    mode: str           # "nan" | "inf"
    leaf_id: int = -1   # resolved lazily from the seed when < 0


@dataclass(frozen=True)
class DropFault:
    worker: int
    start: int
    stop: int


@dataclass(frozen=True)
class WireFault:
    start: int
    stop: int
    n_bits: int = 8     # byte positions XORed per injection


@dataclass(frozen=True)
class StallFault:
    worker: int
    start: int
    stop: int
    ms: int = 1000      # host-side delay per stalled step


@dataclass(frozen=True)
class CrashFault:
    step: int
    # half-open range view, so the shared validation/active_any logic
    # treats a crash like any other single-step fault
    @property
    def start(self):
        return self.step

    @property
    def stop(self):
        return self.step + 1


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, declared fault schedule — see module docstring."""
    n_workers: int
    seed: int = 0
    drops: tuple = ()        # DropFault...
    grad_faults: tuple = ()  # GradFault...
    wire_faults: tuple = ()  # WireFault...
    stalls: tuple = ()       # StallFault...  (host-side)
    crashes: tuple = ()      # CrashFault...  (host-side)

    def __post_init__(self):
        for f in self.drops + self.grad_faults + self.stalls:
            if not 0 <= f.worker < self.n_workers:
                raise ValueError(
                    f"fault worker {f.worker} out of range "
                    f"[0, {self.n_workers})")
        for f in (self.drops + self.grad_faults + self.wire_faults
                  + self.stalls + self.crashes):
            if f.stop <= f.start:
                raise ValueError(f"empty fault step range "
                                 f"[{f.start}, {f.stop})")
        for f in self.stalls:
            if f.ms <= 0:
                raise ValueError(f"stall needs ms > 0, got {f.ms}")

    # ------------------------------------------------------------- drops
    def drop_mask(self, step):
        """``[n_workers]`` bool, False where a drop fault is active at
        ``step`` (ANDed into the participation mask by the optimizer)."""
        step = jnp.asarray(step, jnp.int32)
        mask = jnp.ones((self.n_workers,), jnp.bool_)
        for f in self.drops:
            active = (step >= f.start) & (step < f.stop)
            mask = mask & ~(active
                            & (jnp.arange(self.n_workers) == f.worker))
        return mask

    # ----------------------------------------------------------- grads
    def inject_grads(self, grads, step):
        """Poison the scheduled gradient leaves of the worker-lead grads
        tree (leaves ``[n_workers, ...]``). The faulty leaf index is
        drawn from the plan seed per fault — deterministic, but not
        hand-picked, so the guard is exercised on arbitrary leaves."""
        if not self.grad_faults:
            return grads
        step = jnp.asarray(step, jnp.int32)
        leaves, treedef = jax.tree.flatten(grads)
        rng = np.random.default_rng(self.seed)
        for f in self.grad_faults:
            lid = f.leaf_id if f.leaf_id >= 0 \
                else int(rng.integers(len(leaves)))
            g = leaves[lid]
            active = (step >= f.start) & (step < f.stop)
            poison = jnp.asarray(
                np.nan if f.mode == "nan" else np.inf, g.dtype)
            wsel = jnp.arange(g.shape[0]) == f.worker
            sel = active & wsel.reshape((-1,) + (1,) * (g.ndim - 1))
            leaves[lid] = jnp.where(sel, poison, g)
        return treedef.unflatten(leaves)

    # ------------------------------------------------------------ wire
    def inject_wire(self, buf, step, stage: int = 0,
                    direction: str = "w2s"):
        """XOR seeded byte positions of a gathered u8 wire (sub-)buffer
        when a wire fault is active. Positions/masks are drawn per
        (fault, stage, direction) so staged arms corrupt independent
        sites; clamped to the buffer's byte dim."""
        if not self.wire_faults or direction != "w2s" \
                or buf.dtype != jnp.uint8:
            return buf
        step = jnp.asarray(step, jnp.int32)
        nbytes = buf.shape[-1]
        for fi, f in enumerate(self.wire_faults):
            rng = np.random.default_rng(
                (self.seed, fi, stage, 0 if direction == "w2s" else 1))
            n = min(f.n_bits, nbytes)
            pos = rng.choice(nbytes, size=n, replace=False)
            xor = rng.integers(1, 256, size=n).astype(np.uint8)
            flipped = buf.at[..., pos].set(
                buf[..., pos] ^ jnp.asarray(xor, jnp.uint8))
            active = (step >= f.start) & (step < f.stop)
            buf = jnp.where(active, flipped, buf)
        return buf

    def active_any(self, step):
        """Scalar bool: any declared fault active at ``step``."""
        step = jnp.asarray(step, jnp.int32)
        out = jnp.asarray(False)
        for f in (self.drops + self.grad_faults + self.wire_faults
                  + self.stalls + self.crashes):
            out = out | ((step >= f.start) & (step < f.stop))
        return out

    # ------------------------------------------------- host-side faults
    def stall_ms(self, step: int, attempt: int = 0) -> int:
        """Milliseconds a ``stall`` clause delays host step ``step``
        (0 when none active). Only attempt 0 stalls: the fault models a
        transiently wedged worker, so the supervisor's retry dispatch
        goes through clean."""
        if attempt != 0:
            return 0
        return max((f.ms for f in self.stalls
                    if f.start <= step < f.stop), default=0)

    def host_stall(self, step: int, attempt: int = 0) -> int:
        """Sleep out any active stall fault; returns the ms slept."""
        ms = self.stall_ms(step, attempt)
        if ms:
            time.sleep(ms / 1000.0)
        return ms

    def host_crash(self, step: int, start_step: int = 0) -> None:
        """Hard process exit (``os._exit(CRASH_EXIT)``) when a ``crash``
        clause matches ``step`` — simulated power loss, no atexit/flush.
        Gated on ``start_step == 0`` so a ``--resume`` run (which starts
        past step 0) replays the same schedule without re-crashing."""
        if start_step != 0:
            return
        for f in self.crashes:
            if f.step == step:
                os._exit(CRASH_EXIT)


def parse_faults(spec: str, n_workers: int, seed: int = 0) -> FaultPlan:
    """Parse the CLI fault grammar (module docstring) into a FaultPlan."""
    drops, grads, wires, stalls, crashes = [], [], [], [], []
    for clause in [c.strip() for c in spec.split(",") if c.strip()]:
        m = _CLAUSE_RE.match(clause)
        if not m:
            raise ValueError(f"bad fault clause {clause!r}")
        kind = m.group(1)
        kv = dict(p.split("=", 1) for p in m.group(2).split(":") if p)
        if kind == "crash":
            if "step" not in kv:
                raise ValueError(f"fault clause {clause!r} needs step=s")
            crashes.append(CrashFault(int(kv["step"])))
            continue
        if "steps" not in kv:
            raise ValueError(f"fault clause {clause!r} needs steps=a[-b]")
        a, _, b = kv["steps"].partition("-")
        start, stop = int(a), (int(b) if b else int(a) + 1)
        if kind == "drop":
            drops.append(DropFault(int(kv["w"]), start, stop))
        elif kind in ("nan", "inf"):
            grads.append(GradFault(int(kv["w"]), start, stop, kind,
                                   leaf_id=int(kv.get("leaf", -1))))
        elif kind == "stall":
            stalls.append(StallFault(int(kv["w"]), start, stop,
                                     ms=int(kv.get("ms", 1000))))
        else:  # flip
            wires.append(WireFault(start, stop,
                                   n_bits=int(kv.get("bits", 8))))
    return FaultPlan(n_workers=n_workers, seed=seed, drops=tuple(drops),
                     grad_faults=tuple(grads), wire_faults=tuple(wires),
                     stalls=tuple(stalls), crashes=tuple(crashes))
