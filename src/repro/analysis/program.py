"""ProgramArtifact — one compiled (config, mesh, arm) cell plus the
static expectations the lint rules check it against (DESIGN.md §12).

The artifact bundles the compiled per-device HLO text with everything a
rule needs that the text alone cannot provide: the resolved
:class:`~repro.core.muon.WireBudget` (expected u8 collective
population), the optimizer-state avals on both sides of the step (dtype
drift), the NS bucket shapes and their expected per-device shards
(replication audit), and the donation flag the jit boundary was built
with. Rules stay pure functions ``ProgramArtifact -> [Finding]`` — they
never compile anything themselves, so seeded-violation tests can feed
them hand-written HLO.

``build_cell`` is the matrix builder: it lowers + compiles one reduced
config on an emulated host mesh through the exact ``Trainer.jit_step``
entry point the dry-run uses (device-free: run under
``--xla_force_host_platform_device_count``).

``canonical_hlo`` rewrites a module dump into a form stable across
recompiles of the same program: SSA value names are renumbered by first
appearance and ``metadata={...}`` operand annotations (op names +
source paths — machine-specific) are dropped. Its sha256 is the
lowering-drift fingerprint.
"""
from __future__ import annotations

import hashlib
import math
import re
from dataclasses import dataclass, field
from functools import cached_property
from typing import Any

from repro.analysis import hlo_ir

# ----------------------------------------------------------- canonical HLO

_SSA_RE = re.compile(r"%[\w.\-]+")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _strip_attr(text: str, key: str) -> str:
    """Remove every ``key={...}`` attribute (balanced braces, quote-aware
    — op_name strings may contain arbitrary punctuation)."""
    needle = key + "={"
    out = []
    i = 0
    while True:
        j = text.find(needle, i)
        if j < 0:
            out.append(text[i:])
            return "".join(out)
        out.append(text[i:j].rstrip().rstrip(","))
        k = j + len(needle)
        depth, quoted = 1, False
        while k < len(text) and depth:
            ch = text[k]
            if quoted:
                if ch == "\\":
                    k += 1
                elif ch == '"':
                    quoted = False
            elif ch == '"':
                quoted = True
            elif ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
            k += 1
        i = k


def canonical_hlo(text: str) -> str:
    """The module text modulo SSA numbering and op metadata: value names
    become ``%v<N>`` by order of first appearance, ``metadata={...}``
    and ``/*...*/`` comments are dropped, trailing whitespace is
    stripped. Two compiles of the same program canonicalise
    identically; any real lowering change survives."""
    text = _COMMENT_RE.sub("", text)
    text = _strip_attr(text, "metadata")
    mapping: dict[str, str] = {}

    def sub(m: re.Match) -> str:
        t = m.group(0)
        if t not in mapping:
            mapping[t] = f"%v{len(mapping)}"
        return mapping[t]

    return "\n".join(_SSA_RE.sub(sub, ln.rstrip())
                     for ln in text.splitlines())


def canonical_hash(text: str) -> str:
    return hashlib.sha256(canonical_hlo(text).encode()).hexdigest()[:16]


# ------------------------------------------------------------- header info

def input_output_aliases(hlo_text: str) -> set[int]:
    """Parameter numbers the module header declares input/output aliased
    (``input_output_alias={ {out}: (param, {}, may-alias), ... }``) —
    the buffers donation actually reuses."""
    i = hlo_text.find("input_output_alias={")
    if i < 0:
        return set()
    seg = hlo_text[i + len("input_output_alias={"):]
    depth = 1
    for k, ch in enumerate(seg):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                seg = seg[:k]
                break
    return {int(m.group(1)) for m in re.finditer(r":\s*\((\d+)", seg)}


def entry_param_bytes(comps: dict, entry: str | None = None) -> dict[int, int]:
    """Per-device byte size of each entry parameter, by parameter
    number (the compiled argument the donation audit sizes)."""
    if entry is None:
        entry = hlo_ir.entry_name(comps)
    comp = comps.get(entry)
    if comp is None:
        return {}
    out: dict[int, int] = {}
    for ins in comp.instrs:
        if hlo_ir.base_op(ins.op) == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                out[int(m.group(1))] = comp.sizes.get(ins.name, 0)
    return out


def leaf_entries(tree: Any) -> tuple[tuple[str, tuple, str], ...]:
    """Flatten a pytree of avals/arrays to ``(path, shape, dtype)``
    rows, in jax's flattening order (the compiled argument order)."""
    import jax

    rows = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        rows.append((jax.tree_util.keystr(path), tuple(leaf.shape),
                     str(leaf.dtype)))
    return tuple(rows)


# ---------------------------------------------------------------- artifact

@dataclass(frozen=True)
class BucketAudit:
    """One NS bucket's stacked shape and its expected per-device shard
    under the bucket's ``ns_bucket_pspec`` — the replication rule flags
    dots materialising ``full_shape`` when the two differ."""
    full_shape: tuple[int, ...]
    sharded_shape: tuple[int, ...]
    pspec: str = ""


@dataclass
class ProgramArtifact:
    """One compiled cell of the lint matrix. Only ``cell`` and
    ``hlo_text`` are mandatory — rules skip the checks whose
    expectations are absent, which is how seeded-violation tests
    isolate a single rule."""
    cell: str                      # "arch@mesh/arm"
    hlo_text: str
    meta: dict = field(default_factory=dict)
    budget: Any = None             # core.muon.WireBudget | None
    donate: bool = False
    state_in: tuple = ()           # ((path, shape, dtype), ...)
    state_out: tuple = ()
    buckets: tuple = ()            # (BucketAudit, ...)
    n_flat_args: int | None = None  # expected compiled arg count

    @cached_property
    def comps(self) -> dict:
        return hlo_ir.parse_module(self.hlo_text)

    @cached_property
    def cost(self) -> dict:
        from repro.launch.hlo_cost import analyze

        return analyze(self.hlo_text)

    @cached_property
    def converts(self) -> dict[tuple[str, str], tuple[int, int]]:
        """Module-wide convert summary: (src dtype, dst dtype) ->
        (count, max element count) across every computation (fused
        converts included — fusion bodies are computations too)."""
        out: dict[tuple[str, str], list[int]] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                if hlo_ir.base_op(ins.op) != "convert" or not ins.operands:
                    continue
                src = hlo_ir.SHAPE_RE.search(
                    comp.types.get(ins.operands[0], ""))
                dst = hlo_ir.SHAPE_RE.search(ins.type_str)
                if not (src and dst):
                    continue
                key = (src.group(1), dst.group(1))
                row = out.setdefault(key, [0, 0])
                row[0] += 1
                row[1] = max(row[1], comp.elems.get(ins.name, 0))
        return {k: (v[0], v[1]) for k, v in out.items()}

    @cached_property
    def canonical_hash(self) -> str:
        return canonical_hash(self.hlo_text)

    @cached_property
    def aliased_params(self) -> set[int]:
        return input_output_aliases(self.hlo_text)


# ------------------------------------------------------------ cell builder

def _shard_dim(dim: int, entry: Any, axes: dict[str, int]) -> int:
    if entry is None:
        return dim
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    div = math.prod(axes.get(n, 1) for n in names)
    return dim // div if div and dim % div == 0 else dim


def bucket_audits(buckets, axes: dict[str, int]) -> tuple[BucketAudit, ...]:
    """BucketAudit rows from ``plan.ns_buckets(mesh, fsdp)``: the
    stacked ``[B, m, n]`` shape and its per-device shard under the
    bucket's pspec (identical when the bucket is replicated)."""
    out = []
    for b in buckets:
        full = (b.batch,) + tuple(b.shape)
        spec = tuple(b.pspec) if b.pspec is not None else (None,) * 3
        sharded = tuple(_shard_dim(d, e, axes)
                        for d, e in zip(full, spec))
        out.append(BucketAudit(full, sharded, str(b.pspec)))
    return tuple(out)


def build_cell(arch: str, arm: str = "default", *,
               mesh_shape: tuple[int, int] = (4, 2),
               w2s: str = "top10+natural", s2w: str = "natural",
               seq: int = 32, batch: int = 8,
               donate: bool = False, **tcfg_overrides) -> ProgramArtifact:
    """Lower + compile one reduced (arch, mesh, arm) cell through the
    real ``Trainer.jit_step`` entry point and bundle it with the
    expectations the rules check. Device-free, but the process must
    expose ``prod(mesh_shape)`` (emulated) devices —
    ``launch.dryrun.ensure_host_devices`` before first jax use."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.models.api import build_model, input_specs
    from repro.train.trainer import Trainer, TrainerConfig

    n_dev = math.prod(mesh_shape)
    if len(jax.devices()) < n_dev:
        raise RuntimeError(
            f"build_cell needs {n_dev} devices, have {len(jax.devices())} "
            "(ensure_host_devices before first jax use)")
    mesh = Mesh(np.array(jax.devices()[:n_dev]).reshape(mesh_shape),
                ("data", "model"))
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    n_w = mesh_shape[0]
    tr = Trainer(model, TrainerConfig(
        n_workers=n_w, beta=0.5, w2s=w2s, s2w=s2w, use_pallas=False,
        remat=False, donate=donate, **tcfg_overrides), mesh=mesh)
    shape = ShapeSpec("lint", "train", seq, batch)
    batch_specs = input_specs(cfg, shape, n_workers=n_w)
    state = tr.state_shapes()
    jitted = tr.jit_step(batch_specs)
    t_aval = jax.ShapeDtypeStruct((), jnp.float32)
    compiled = jitted.lower(state, batch_specs, t_aval).compile()

    state_out, _aux = jax.eval_shape(tr.make_step(), state, batch_specs,
                                     t_aval)
    plan = tr.layer_plan()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cell = f"{arch}@{'x'.join(map(str, mesh_shape))}/{arm}"
    n_flat = (len(jax.tree.leaves(state)) + len(jax.tree.leaves(batch_specs))
              + 1)
    return ProgramArtifact(
        cell=cell,
        hlo_text=compiled.as_text(),
        meta={"arch": arch, "arm": arm, "mesh": dict(axes),
              "w2s": w2s, "s2w": s2w, "donate": donate,
              **{k: str(v) for k, v in tcfg_overrides.items()}},
        budget=tr.wire_budget(),
        donate=donate,
        state_in=leaf_entries(state),
        state_out=leaf_entries(state_out),
        buckets=bucket_audits(
            plan.ns_buckets(mesh=mesh, fsdp=tr.tcfg.fsdp), axes),
        n_flat_args=n_flat)
