"""The lint CLI (DESIGN.md §12):

    PYTHONPATH=src python -m repro.analysis.lint --matrix
    PYTHONPATH=src python -m repro.analysis.lint --configs nanogpt-124m \\
        --arms default,donate --out results/lint.jsonl
    PYTHONPATH=src python -m repro.analysis.lint --matrix --update-baseline

Device-free: every cell compiles a reduced config on an emulated 4x2
host mesh (``--xla_force_host_platform_device_count``), runs all rules
over the lowered+compiled program, and diffs the findings against the
committed ``LINT_BASELINE.json``. Exit status 1 iff any error/warn
finding is not in the baseline allowlist — info findings (donation
savings on non-donate arms, unrecorded hashes) only print.

The matrix: every arch gets the ``default`` arm; nanogpt additionally
runs the arms that pin config-resolution claims — ``mono``
(wire_stages=1), ``donate``, plus two equality pairs (``full-explicit``
and ``s2w-forced`` must lower hash-identical to ``default``, the §9/§11
"auto resolution is the explicit arm" statements made checkable).
"""
from __future__ import annotations

import argparse
import json
import sys

MATRIX_ARCHS = ("nanogpt-124m", "granite-3-2b", "deepseek-v3-671b",
                "whisper-small")

# arm name -> build_cell overrides (w2s/s2w/donate are builder kwargs,
# the rest flow into TrainerConfig)
ARMS: dict[str, dict] = {
    "default": {},
    "mono": {"wire_stages": 1},
    "donate": {"donate": True},
    "full-explicit": {"participation": "full", "nonfinite_guard": False},
    "s2w-forced": {"wire_pack_s2w": True},
}

# nanogpt carries the arm sweep; the other archs pin the default arm only
ARCH_ARMS: dict[str, tuple[str, ...]] = {
    "nanogpt-124m": ("default", "mono", "donate", "full-explicit",
                     "s2w-forced"),
}

# arms whose lowering is claimed bit-identical: hash-compared in-process
EQUAL_ARMS = (("default", "full-explicit"), ("default", "s2w-forced"))


def lint_matrix(archs, arms_filter=None, *, baseline_doc, only=None,
                log=print):
    """Compile each (arch, arm) cell, run the rules, and return
    ``(findings, hashes)``. Imports jax lazily so ``ensure_host_devices``
    in ``main`` wins the backend-init race."""
    from repro.analysis.baseline import hashes_comparable
    from repro.analysis.program import build_cell
    from repro.analysis.rules import equality_findings, run_rules

    ctx = {"baseline_hashes": baseline_doc.get("hashes", {}),
           "hashes_comparable": hashes_comparable(baseline_doc)}
    findings, hashes = [], {}
    for arch in archs:
        arts = {}
        for arm in ARCH_ARMS.get(arch, ("default",)):
            if arms_filter and arm not in arms_filter:
                continue
            over = ARMS[arm]
            log(f"lint: compiling {arch}/{arm} ...")
            art = build_cell(arch, arm, **over)
            arts[arm] = art
            hashes[art.cell] = art.canonical_hash
            findings.extend(run_rules(art, ctx, only=only))
        for a, b in EQUAL_ARMS:
            if a in arts and b in arts:
                findings.extend(equality_findings(arts[a], arts[b]))
    return findings, hashes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static lint of compiled step programs (§12)")
    ap.add_argument("--matrix", action="store_true",
                    help="run the full default matrix "
                         f"({', '.join(MATRIX_ARCHS)})")
    ap.add_argument("--configs", default=None,
                    help="comma-separated arch subset")
    ap.add_argument("--arms", default=None,
                    help="comma-separated arm subset "
                         f"({', '.join(ARMS)})")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--baseline", default="LINT_BASELINE.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline with current hashes "
                         "(and allowlist any surviving findings)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also emit findings as schema-versioned JSONL "
                         "(obs.sink kind=lint)")
    args = ap.parse_args(argv)

    if not args.matrix and not args.configs:
        ap.error("pick --matrix or --configs")
    archs = (args.configs.split(",") if args.configs
             else list(MATRIX_ARCHS))
    arms_filter = set(args.arms.split(",")) if args.arms else None
    only = set(args.rules.split(",")) if args.rules else None

    # before any jax backend init: the matrix needs 8 emulated devices
    from repro.launch.dryrun import ensure_host_devices
    ensure_host_devices(8)

    from repro.analysis.baseline import load_baseline, save_baseline

    baseline_doc = load_baseline(args.baseline)
    findings, hashes = lint_matrix(archs, arms_filter,
                                   baseline_doc=baseline_doc, only=only)

    if args.out:
        from repro.obs.sink import MetricsWriter
        with MetricsWriter(args.out) as w:
            for f in findings:
                w.write("lint", **f.to_record())

    allow = set(baseline_doc.get("findings", []))
    new = [f for f in findings
           if f.level in ("error", "warn") and f.fingerprint not in allow]
    for f in findings:
        tag = ("baselined" if f.fingerprint in allow
               else f.level)
        print(f"[{tag:9s}] {f.rule:15s} {f.cell:32s} {f.message}")
        if f.data and f in new:
            print(f"{'':11s}{json.dumps(f.data, default=str)[:200]}")
    print(f"lint: {len(findings)} finding(s) over {len(hashes)} cell(s); "
          f"{len(new)} not in baseline")

    if args.update_baseline:
        # record what fires *now* — keeps still-live allowlist entries
        # (updating after a green run must not wipe them) and prunes
        # entries that stopped firing
        save_baseline(args.baseline, hashes,
                      [f.fingerprint for f in findings
                       if f.level in ("error", "warn")])
        print(f"lint: baseline written to {args.baseline}")
        return 0
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
