"""Module IR over XLA's HLO text dump — the shared parsing layer.

``launch/hlo_cost.py`` (trip-count-aware cost model) and
``launch/hlo_analysis.py`` (collective-byte accounting) grew the same
primitives independently: the dtype-width table, the shape regex, the
depth-aware operand splitter, the collective-op classifier. This module
is the single copy both build on, and the substrate the ``analysis``
rule engine (DESIGN.md §12) walks.

The IR is deliberately textual: ``parse_module`` turns one per-device
HLO module dump into ``{name: Computation}`` where each ``Computation``
holds its instruction list plus per-value size/type tables. That is
enough structure for byte accounting, FLOP models, async-pair windows
and the lint rules, while staying independent of jaxlib internals
(the text format is the one XLA artifact stable enough to pin in
hand-written regression tests — see tests/test_hlo_cost.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(\(.*)?\{\s*$")
INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+) = ((?:\([^=]*?\)|[^(=]*?)) ([\w\-]+)\((.*)$")
PARAM_RE = re.compile(r"(%?[\w.\-]+):\s*((?:\w+\[[\d,]*\][^,)]*|\([^)]*\)))")
CALLED_RE = re.compile(r"(?:calls|to_apply|body)=(%?[\w.\-]+)")
COND_RE = re.compile(r"condition=(%?[\w.\-]+)")


def shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(element count, byte size) of an HLO type string (sums tuples)."""
    elems = tot = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * DTYPE_BYTES[dt]
    return elems, tot


def type_bytes(type_str: str) -> int:
    """Byte size of an HLO type string (handles tuples)."""
    return shape_elems_bytes(type_str)[1]


def first_shape_dims(type_str: str) -> list[int]:
    """Dims of the first array shape in a type string."""
    m = SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def base_op(op: str) -> str:
    """Opcode with the SSA-uniquifying digit suffix stripped
    (``all-gather-start.42`` -> ``all-gather-start``)."""
    return op.rstrip(".0123456789")


def collective_kind(op: str) -> tuple[str | None, str]:
    """(collective kind, phase) of an opcode: phase is ``"start"`` /
    ``"done"`` for async halves, ``""`` for sync collectives; kind is
    None for non-collectives."""
    base = base_op(op)
    for kind in COLLECTIVES:
        if base.startswith(kind):
            if base == kind + "-start":
                return kind, "start"
            if base == kind + "-done":
                return kind, "done"
            if base == kind:
                return kind, ""
    return None, ""


def operand_name(o: str) -> str:
    """Reference name of one operand. Depending on XLA version the text
    form is either bare (``%foo.1``) or typed
    (``f32[1,2]{1,0} %foo.1``); take the trailing %-token."""
    toks = o.split()
    for t in reversed(toks):
        if t.startswith("%"):
            return t.lstrip("%")
    return toks[-1].lstrip("%") if toks else o


def split_top(s: str) -> list[str]:
    """Split an operand list at depth 0 commas."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def operand_span(rest: str) -> tuple[str, str]:
    """Split the text after an instruction's opening paren into
    (operand list, trailing attributes) at the matching close paren."""
    depth = 0
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    return rest[:end], rest[end + 1:]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    sizes: dict = field(default_factory=dict)     # name -> bytes
    elems: dict = field(default_factory=dict)     # name -> element count
    types: dict = field(default_factory=dict)     # name -> type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        # strip /*index=N*/ comments: they contain '=' and '(' characters
        # that break type/operand parsing of long tuple-typed instructions
        line = re.sub(r"/\*.*?\*/", "", raw.rstrip())
        if cur is None:
            m = COMP_HEADER_RE.match(line.strip())
            head = line.split("{")[0]
            if m and " = " not in head:
                cur = Computation(m.group(1).lstrip("%"))
                # header params carry types
                for pname, ptype in PARAM_RE.findall(line):
                    n = pname.lstrip("%")
                    e, b = shape_elems_bytes(ptype)
                    cur.sizes[n] = b
                    cur.elems[n] = e
                    cur.types[n] = ptype
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = INSTR_RE.match(line)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        type_str = m.group(2).strip()
        op = m.group(3)
        span, attrs = operand_span(m.group(4))
        ops = [operand_name(o.strip()) for o in split_top(span)
               if o.strip()]
        e, b = shape_elems_bytes(type_str)
        cur.sizes[name] = b
        cur.elems[name] = e
        cur.types[name] = type_str
        cur.instrs.append(Instr(name, type_str, op, ops, attrs, line))
    return comps


def entry_name(comps: dict[str, Computation]) -> str | None:
    """The entry computation: the ``main``-named one when present (the
    jit entry), else the first parsed."""
    entry = None
    for name in comps:
        if entry is None or name.startswith("main"):
            entry = name
    return entry
