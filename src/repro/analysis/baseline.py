"""LINT_BASELINE.json — the committed lint baseline (DESIGN.md §12).

Schema (one JSON object):

    {"schema": "repro.lint-baseline/v1",
     "jax": "<jax.__version__ at record time>",
     "hashes": {"<cell>": "<canonical HLO hash>", ...},
     "findings": ["<rule>|<cell>|<message>", ...]}

``hashes`` feeds the lowering-drift rule and is only compared when the
running jax version matches the recorded one (a jax upgrade legitimately
changes every lowering; within-run arm-equality pairs are enforced
regardless). ``findings`` is the allowlist of error/warn fingerprints
the CLI tolerates — the committed baseline keeps it empty, so any
finding fails CI until either the program or the baseline changes in
the same PR.
"""
from __future__ import annotations

import json

BASELINE_SCHEMA = "repro.lint-baseline/v1"
DEFAULT_PATH = "LINT_BASELINE.json"


def load_baseline(path: str) -> dict:
    """Parsed baseline, or an empty one if the file doesn't exist (the
    first --update-baseline run bootstraps it)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return {"schema": BASELINE_SCHEMA, "jax": None, "hashes": {},
                "findings": []}
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: unknown baseline schema "
                         f"{doc.get('schema')!r}")
    doc.setdefault("hashes", {})
    doc.setdefault("findings", [])
    return doc


def save_baseline(path: str, hashes: dict[str, str],
                  fingerprints: list[str]) -> dict:
    try:
        import jax
        jax_version = jax.__version__
    except Exception:
        jax_version = None
    doc = {"schema": BASELINE_SCHEMA, "jax": jax_version,
           "hashes": dict(sorted(hashes.items())),
           "findings": sorted(set(fingerprints))}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def hashes_comparable(doc: dict) -> bool:
    """Baseline hashes are only meaningful under the jax version that
    produced them."""
    try:
        import jax
        return doc.get("jax") == jax.__version__
    except Exception:
        return False
