"""The lint rule registry (DESIGN.md §12).

Every rule is a pure function ``(ProgramArtifact, ctx) -> [Finding]``
registered under a stable name. Rules only read the artifact — they
never compile, so seeded-violation tests can drive each one with a
hand-written module and assert it trips exactly that rule.

Rule catalog:

``wire-budget``     exactly the WireBudget's u8 all-gather population,
                    byte-for-byte per stage sub-buffer, both directions;
                    residual u8 all-reduce bounded by one s2w buffer.
``replication``     no large dot materialises a full NS bucket stack
                    whose pspec says it should be sharded (the PR-3
                    concat-drops-shardings FLOP-blowup class).
``dtype-upcast``    no f64 anywhere, no silent u8-wire -> float widening,
                    no state-leaf dtype drift across the step.
``donation``        with donate=True every large state leaf is
                    input/output aliased; without it, report the
                    double-buffered bytes on offer.
``host-sync``       no infeed/outfeed/send/recv or host-callback
                    custom-calls inside the jitted step.
``lowering-drift``  canonical HLO hash matches the committed baseline
                    (same-jax-version only); arm pairs claimed
                    bit-identical hash-compare via ``equality_findings``.

``ctx`` keys: ``baseline_hashes`` ({cell: hash}) and
``hashes_comparable`` (False when the baseline was recorded under a
different jax version — drift comparisons are skipped, everything else
still runs).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable

from repro.analysis import hlo_ir
from repro.analysis.program import ProgramArtifact, entry_param_bytes


@dataclass
class Finding:
    rule: str
    cell: str
    level: str              # "error" | "warn" | "info"
    message: str
    data: dict = field(default_factory=dict)

    @property
    def fingerprint(self) -> str:
        """Stable identity for baselining — deliberately excludes
        ``data`` (instruction names change across recompiles)."""
        return f"{self.rule}|{self.cell}|{self.message}"

    def to_record(self) -> dict:
        return {"rule": self.rule, "cell": self.cell, "level": self.level,
                "message": self.message, "data": self.data}


RULES: dict[str, Callable] = {}


def rule(name: str):
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def run_rules(art: ProgramArtifact, ctx: dict | None = None,
              only=None) -> list[Finding]:
    ctx = ctx or {}
    out: list[Finding] = []
    for name, fn in RULES.items():
        if only is not None and name not in only:
            continue
        out.extend(fn(art, ctx))
    return out


# -------------------------------------------------------------- wire budget

def wire_budget_findings(u8_pairs: list, budget, cell: str = "?"
                         ) -> list[Finding]:
    """The two-direction wire invariant as findings: the u8 collective
    pair records (from ``hlo_cost.analyze``) must contain *exactly* the
    budget's all-gather population — one gather per stage sub-buffer,
    byte-equal, both directions — plus at most one s2w broadcast's worth
    of model-axis u8 repack traffic (§9). Shared by the ``wire-budget``
    rule and tests/test_sharding's SPMD assertions, so the test suite
    and the lint CLI cannot drift apart."""
    if budget is None or not (budget.pack_w2s or budget.pack_s2w):
        return []
    from repro.launch.hlo_analysis import attribute_u8_directions

    # Direction gathers span the full worker group; u8 collectives over a
    # smaller replica group are the model-axis TP repack (§9), which the
    # partitioner is free to lower as all-reduces, sub-group all-gathers
    # or collective-permutes (deepseek does all three). Pairs without
    # group info (hlo_cost.analyze's records) keep the legacy behaviour:
    # every all-gather is a direction candidate.
    nw = getattr(budget, "n_workers", 1)
    gathers, residual = [], []
    for p in u8_pairs:
        g = p.get("group")
        if p["kind"] == "all-gather" and (g is None or nw <= 1 or g == nw):
            gathers.append(p)
        else:
            residual.append(p)
    split = attribute_u8_directions(gathers, budget.w2s_sizes,
                                    budget.s2w_sizes)
    f: list[Finding] = []
    for d, sizes in (("w2s", budget.w2s_sizes), ("s2w", budget.s2w_sizes)):
        got = split[d]["count"]
        if got != len(sizes):
            f.append(Finding(
                "wire-budget", cell, "error",
                f"{d}: {got} u8 all-gathers byte-matched, expected "
                f"{len(sizes)}",
                {"direction": d, "matched": got,
                 "expected_sizes": [int(s) for s in sizes],
                 "missing": split["missing"].get(d, [])}))
    if split["unmatched_bytes"]:
        f.append(Finding(
            "wire-budget", cell, "error",
            f"{len(split['unmatched_bytes'])} u8 all-gathers no wire "
            "direction expects",
            {"bytes": split["unmatched_bytes"]}))
    if split["missing"].get("orphan"):
        f.append(Finding(
            "wire-budget", cell, "error",
            "u8 all-gather-start without a matching done (truncated "
            "module text?)",
            {"bytes": split["missing"]["orphan"]}))
    repack_kinds = {"all-reduce", "all-gather", "collective-permute"}
    bad_kinds = sorted({p["kind"] for p in residual} - repack_kinds)
    if bad_kinds:
        f.append(Finding(
            "wire-budget", cell, "error",
            f"u8 payload in unexpected collectives: {', '.join(bad_kinds)}",
            {"kinds": bad_kinds}))
    repack = sum(int(round(p.get("count", 1.0))) * int(p["bytes"])
                 for p in residual if p["kind"] in repack_kinds)
    if repack > budget.s2w_nbytes:
        f.append(Finding(
            "wire-budget", cell, "error",
            f"u8 repack bytes {repack} exceed one s2w broadcast "
            f"({budget.s2w_nbytes}) — TP repack bound",
            {"repack_bytes": repack, "s2w_nbytes": budget.s2w_nbytes}))
    return f


def _group_size(attrs: str) -> int | None:
    """Replica-group size of a collective from its attribute text —
    iota form ``replica_groups=[G,S]<=...`` or the explicit
    ``replica_groups={{0,1,..},..}`` list. None when absent
    (collective-permutes carry source_target_pairs instead)."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return m.group(1).count(",") + 1
    return None


def entry_u8_pairs(comps: dict) -> list[dict]:
    """u8 collective records from the entry's unrolled (non-while)
    region — the optimizer phases live here, so u8 payloads riding a
    scanned layer loop never enter the wire budget. Each record carries
    its replica-group size (``group``) so the attribution can tell
    worker-axis direction gathers from model-axis repack traffic."""
    pairs = []
    for nm in _entry_reachable(comps, hlo_ir.entry_name(comps)):
        comp = comps[nm]
        for ins in comp.instrs:
            kind, phase = hlo_ir.collective_kind(ins.op)
            if kind is None or phase == "done":
                continue
            if not any(comp.types.get(o, "").startswith("u8[")
                       for o in ins.operands):
                continue
            b = sum(comp.sizes.get(o, 0) for o in ins.operands)
            p = {"kind": kind, "bytes": float(b), "u8": True,
                 "count": 1.0, "name": ins.name}
            g = _group_size(ins.attrs)
            if g is not None:
                p["group"] = g
            if phase == "start" and not any(
                    hlo_ir.base_op(o.op) == kind + "-done"
                    and ins.name in o.operands for o in comp.instrs):
                p["orphan"] = True
            pairs.append(p)
    return pairs


@rule("wire-budget")
def _wire_budget(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    if art.budget is None:
        return []
    return wire_budget_findings(entry_u8_pairs(art.comps), art.budget,
                                art.cell)


# -------------------------------------------------------------- replication

MIN_REPL_DOT_FLOPS = 1 << 16   # ignore trinket dots (scalars, tiny tiles)


def _entry_reachable(comps: dict, entry: str) -> list[str]:
    """Computation names reachable from entry WITHOUT entering while
    bodies. The model's scan-over-layers lives inside whiles; the NS
    chains the replication audit cares about are unrolled in the entry
    (via fusions/calls/conditionals), so stopping at whiles removes the
    forward/backward pass's dot population from consideration."""
    seen: list[str] = []
    seen_set: set[str] = set()
    stack = [entry]
    while stack:
        nm = stack.pop()
        if nm in seen_set or nm not in comps:
            continue
        seen_set.add(nm)
        seen.append(nm)
        for ins in comps[nm].instrs:
            if hlo_ir.base_op(ins.op) == "while":
                continue
            tail = ins.attrs + " " + ins.line
            for m in hlo_ir.CALLED_RE.finditer(tail):
                stack.append(m.group(1).lstrip("%"))
            bm = re.search(r"branch_computations=\{([^}]*)\}", tail)
            if bm:
                stack.extend(x.strip().lstrip("%")
                             for x in bm.group(1).split(",") if x.strip())
    return seen


@rule("replication")
def _replication(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    # Targets: the full stacked [B, m, n] shape (and its transpose) of
    # every bucket whose pspec shards it. A dot producing or consuming
    # that exact shape ran the NS chain replicated — the per-device
    # shard never has those dims, so legit sharded chains can't match.
    targets: dict[tuple, "tuple[str, tuple]"] = {}
    for b in art.buckets:
        if len(b.full_shape) != 3 or b.sharded_shape == b.full_shape:
            continue
        bb, m, n = b.full_shape
        for t in {(bb, m, n), (bb, n, m)}:
            targets.setdefault(t, (b.pspec, b.sharded_shape))
    if not targets:
        return []
    from repro.launch.hlo_cost import dot_flops

    comps = art.comps
    hits: dict[tuple, list[str]] = {}
    for nm in _entry_reachable(comps, hlo_ir.entry_name(comps)):
        comp = comps[nm]
        for ins in comp.instrs:
            if hlo_ir.base_op(ins.op) not in ("dot", "dot-general"):
                continue
            shapes = [tuple(hlo_ir.first_shape_dims(ins.type_str))]
            shapes += [tuple(hlo_ir.first_shape_dims(comp.types.get(o, "")))
                       for o in ins.operands[:2]]
            hit = next((s for s in shapes if s in targets), None)
            if hit is None or dot_flops(ins, comp) < MIN_REPL_DOT_FLOPS:
                continue
            hits.setdefault(hit, []).append(ins.name)
    out = []
    for hit, names in sorted(hits.items()):
        pspec, sharded = targets[hit]
        out.append(Finding(
            "replication", art.cell, "error",
            f"dot materialises full NS bucket stack "
            f"{'x'.join(map(str, hit))} despite pspec {pspec} "
            f"(per-device {'x'.join(map(str, sharded))})",
            {"count": len(names), "instrs": names[:8]}))
    return out


# ------------------------------------------------------------- dtype upcast

U8_UPCAST_MIN_ELEMS = 1024     # small index/flag converts are fine


@rule("dtype-upcast")
def _dtype_upcast(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    f: list[Finding] = []
    n64, example = 0, ""
    for comp in art.comps.values():
        for ins in comp.instrs:
            if "f64[" in ins.type_str:
                n64 += 1
                example = example or ins.name
    if n64:
        f.append(Finding(
            "dtype-upcast", art.cell, "error",
            f"{n64} instruction(s) produce f64 values",
            {"example": example}))
    for (src, dst), (count, max_elems) in sorted(art.converts.items()):
        if src == "u8" and dst.startswith("f") \
                and max_elems >= U8_UPCAST_MIN_ELEMS:
            f.append(Finding(
                "dtype-upcast", art.cell, "error",
                f"u8 -> {dst} convert widens wire bytes to float "
                f"({max_elems} elements)",
                {"count": count, "max_elems": max_elems}))
    if art.state_in and len(art.state_in) == len(art.state_out):
        for (pi, si, di), (_po, _so, do) in zip(art.state_in,
                                                art.state_out):
            if di != do:
                f.append(Finding(
                    "dtype-upcast", art.cell, "error",
                    f"state leaf {pi} dtype drifts {di} -> {do} across "
                    "the step"))
    return f


# ----------------------------------------------------------------- donation

DONATE_MIN_BYTES = 1 << 16     # leaves below 64 KiB may legally not alias


@rule("donation")
def _donation(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    if not art.state_in:
        return []
    pbytes = entry_param_bytes(art.comps)
    n_state = len(art.state_in)
    f: list[Finding] = []
    if (art.n_flat_args is not None and pbytes
            and len(pbytes) != art.n_flat_args):
        f.append(Finding(
            "donation", art.cell, "warn",
            f"compiled entry has {len(pbytes)} parameters, expected "
            f"{art.n_flat_args} — argument pruning, positional audit "
            "may misattribute",
            {"params": len(pbytes), "expected": art.n_flat_args}))
    state_bytes = sum(pbytes.get(i, 0) for i in range(n_state))
    if not art.donate:
        if state_bytes >= DONATE_MIN_BYTES:
            f.append(Finding(
                "donation", art.cell, "info",
                f"state not donated: {state_bytes} bytes/device "
                "double-buffered (--donate to alias in place)",
                {"state_bytes": state_bytes}))
        return f
    missing = [i for i in range(n_state)
               if pbytes.get(i, 0) >= DONATE_MIN_BYTES
               and i not in art.aliased_params]
    if missing:
        tot = sum(pbytes[i] for i in missing)
        f.append(Finding(
            "donation", art.cell, "error",
            f"{len(missing)} donated state leaves not input/output "
            f"aliased ({tot} bytes/device still double-buffered)",
            {"paths": [art.state_in[i][0] for i in missing[:8]],
             "bytes": tot}))
    return f


# ---------------------------------------------------------------- host sync

_HOST_OPS = {"infeed", "outfeed", "send", "send-done", "recv", "recv-done"}
_HOST_TARGET_MARKERS = ("callback", "host", "infeed", "outfeed")


@rule("host-sync")
def _host_sync(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    hits: dict[str, list[str]] = {}
    for comp in art.comps.values():
        for ins in comp.instrs:
            base = hlo_ir.base_op(ins.op)
            if base in _HOST_OPS:
                hits.setdefault(base, []).append(ins.name)
            elif base == "custom-call":
                m = re.search(r'custom_call_target="([^"]*)"',
                              ins.attrs + " " + ins.line)
                tgt = m.group(1) if m else ""
                # device custom-calls ('TopK', cublas, ...) are fine;
                # only targets that round-trip through the host block
                # the step on Python / transfer latency
                if any(k in tgt.lower() for k in _HOST_TARGET_MARKERS):
                    hits.setdefault(f'custom-call "{tgt}"',
                                    []).append(ins.name)
    return [Finding(
        "host-sync", art.cell, "error",
        f"host round-trip in jitted step: {what} x{len(names)}",
        {"instrs": names[:8]})
        for what, names in sorted(hits.items())]


# ----------------------------------------------------------- lowering drift

@rule("lowering-drift")
def _lowering_drift(art: ProgramArtifact, ctx: dict) -> list[Finding]:
    hashes = ctx.get("baseline_hashes") or {}
    h = art.canonical_hash
    if art.cell not in hashes:
        return [Finding("lowering-drift", art.cell, "info",
                        f"no baseline hash recorded (current {h})")]
    if not ctx.get("hashes_comparable", True):
        return []      # baseline from a different jax version
    if hashes[art.cell] != h:
        return [Finding(
            "lowering-drift", art.cell, "warn",
            f"canonical HLO hash drifted {hashes[art.cell]} -> {h} "
            "(re-baseline if intended)")]
    return []


def equality_findings(a: ProgramArtifact, b: ProgramArtifact
                      ) -> list[Finding]:
    """Arm-bit-equality claims (§10/§11 'lowers identically') as a hash
    comparison between two artifacts compiled in the same process —
    always enforceable, no baseline or version gate involved."""
    if a.canonical_hash != b.canonical_hash:
        return [Finding(
            "lowering-drift", f"{a.cell}~{b.cell}", "error",
            "arms claimed bit-identical lower differently "
            f"({a.canonical_hash} != {b.canonical_hash})")]
    return []
