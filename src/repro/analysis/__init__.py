"""Static analysis over lowered/compiled programs (DESIGN.md §12).

``hlo_ir``    — the shared HLO text IR (parser + byte/shape tables) that
                ``launch/hlo_cost.py`` and ``launch/hlo_analysis.py``
                are built on.
``program``   — ``ProgramArtifact``: one compiled (config, mesh, arm)
                cell bundled with the static expectations the rules
                check it against (wire budget, state avals, buckets).
``rules``     — the rule registry: pure functions
                ``ProgramArtifact -> [Finding]``.
``baseline``  — committed-findings/hash baseline (LINT_BASELINE.json).
``lint``      — the ``python -m repro.analysis.lint`` CLI.
"""
