"""Deterministic synthetic LM data: Zipf-Markov token streams.

Tokens follow a per-worker affine-Markov chain with Zipf-distributed
innovations:

    x_{t+1} = (a_j * x_t + b_j + z_t) mod V,     z_t ~ Zipf-ish(V)

so the stream has (a) a Zipf marginal like natural text and (b) learnable
bigram structure that differs across workers — the paper's heterogeneous
setting (distinct D_j per worker, §1.1) in miniature. A model that learns
the per-worker transition laws drives the loss well below the unigram
entropy, so loss curves are meaningful for the Figure 1/2 reproductions.

Everything is a pure function of (seed, step, worker): batches are
reproducible, resumable and need no filesystem.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec


def _zipf(key: jax.Array, shape, vocab: int) -> jax.Array:
    """Approximate Zipf(1) sampler via the inverse-CDF of a log-uniform."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    return jnp.clip(jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1,
                    0, vocab - 1).astype(jnp.int32)


@dataclass(frozen=True)
class SyntheticLM:
    cfg: ArchConfig
    shape: ShapeSpec
    n_workers: int = 1
    seed: int = 0

    def _tokens(self, key: jax.Array, lead: tuple[int, ...],
                seq: int) -> jax.Array:
        v = self.cfg.vocab
        _, _, k2, k3 = jax.random.split(key, 4)
        # per-worker Markov laws: fixed across steps (they are what the
        # model learns), derived from the seed only
        law = jax.random.key(self.seed + 1)
        k0, k1 = jax.random.split(law)
        n_w = lead[0] if len(lead) == 2 else 1
        a = 1 + 2 * jax.random.randint(k0, (n_w,), 0, 16)     # odd multiplier
        b = jax.random.randint(k1, (n_w,), 0, v)
        x0 = _zipf(k2, lead, v)
        k3a, k3b = jax.random.split(k3)
        z = _zipf(k3a, lead + (seq,), v)
        # 85% of transitions follow the worker's deterministic affine law,
        # 15% jump to a fresh Zipf sample: strong learnable bigram signal
        # on top of a Zipf-ish marginal.
        follow = jax.random.bernoulli(k3b, 0.85, lead + (seq,))
        a = a.reshape((n_w,) + (1,) * (len(lead) - 1)) if len(lead) == 2 \
            else a[0]
        b = b.reshape((n_w,) + (1,) * (len(lead) - 1)) if len(lead) == 2 \
            else b[0]

        def step(x, zf):
            z_t, f_t = zf
            x = jnp.where(f_t, (a * x + b) % v, z_t)
            return x, x

        _, toks = jax.lax.scan(
            step, x0, (jnp.moveaxis(z, -1, 0), jnp.moveaxis(follow, -1, 0)))
        return jnp.moveaxis(toks, 0, -1)

    def batch_at(self, step: int) -> dict:
        """Materialise the batch for a given global step (jit-able)."""
        cfg, sh = self.cfg, self.shape
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        if sh.kind == "train":
            lead = (self.n_workers, sh.batch // self.n_workers)
        else:
            lead = (sh.batch,)
        kt, ke = jax.random.split(key)
        toks = self._tokens(kt, lead, sh.seq + 1)
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if sh.kind == "prefill":
            batch.pop("labels")
        if cfg.family == "vlm":
            # stubbed vision frontend: pseudo patch embeddings + M-RoPE ids
            emb = jax.random.normal(ke, lead + (sh.seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype)) * 0.02
            pos = jnp.broadcast_to(
                jnp.arange(sh.seq)[:, None], lead + (sh.seq, 3))
            batch = {"embeds": emb, "pos": pos, **{
                k: v for k, v in batch.items() if k == "labels"}}
        if cfg.family == "audio":
            # stubbed conv/mel frontend: pseudo frame embeddings
            frames = jax.random.normal(
                ke, lead + (cfg.encoder.n_frames, cfg.d_model),
                jnp.dtype(cfg.dtype)) * 0.02
            batch["frames"] = frames
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
