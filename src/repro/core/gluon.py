"""Reference (uncompressed, single-node) Gluon / Muon / Scion (§B.1).

Independent implementation of the LMO-based family the paper builds on:

    M_i <- (1 - beta_i) M_i + beta_i G_i
    X_i <- X_i + t_i * LMO_{B(0,1)}(M_i)          (eq. (7))

Used (a) as the uncompressed baseline in all benchmarks and (b) as the
ground truth for the exact-recovery test: EF21-Muon with identity
compressors and n_workers = 1 must reproduce these iterates bit-for-bit
(paper §3, "Role of Compression").

With spectral LMOs on hidden layers this is Muon; adding sign LMOs for
embedding-like layers gives Scion; arbitrary per-layer norms give Gluon.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.layerwise import vmap_n

from .lmo import lmo_direction


def gluon_init(params: Any) -> dict:
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def gluon_update(params: Any, grads: Any, opt_state: dict, metas: Any,
                 t: jax.Array | float, beta: float = 0.1,
                 ns_steps: int = 5, use_pallas="auto") -> tuple[Any, dict]:
    """One Gluon step; returns (new_params, new_opt_state)."""
    treedef = jax.tree.structure(params)
    metas_l = treedef.flatten_up_to(metas)
    m_new = jax.tree.map(
        lambda m, g: (1.0 - beta) * m + beta * g.astype(jnp.float32),
        opt_state["m"], grads)
    new_params = []
    for x, m, meta in zip(treedef.flatten_up_to(params),
                          treedef.flatten_up_to(m_new), metas_l):
        radius = jnp.asarray(t, jnp.float32) * meta.radius_scale

        def upd(x, g, meta=meta, radius=radius):
            d = lmo_direction(g, meta.lmo, ns_steps=ns_steps,
                              use_pallas=use_pallas)
            return (x.astype(jnp.float32)
                    + radius * d.astype(jnp.float32)).astype(x.dtype)

        new_params.append(vmap_n(upd, meta.stack_dims)(x, m))
    return treedef.unflatten(new_params), {
        "step": opt_state["step"] + 1, "m": m_new}
