"""Contractive compressors — Euclidean and non-Euclidean (Def. 1, §D).

Functional API (jit-safe, fixed payload shapes):

    comp = TopK(fraction=0.1)
    state = comp.init(key, shape, dtype)          # sketches / PRNG, may be {}
    payload, state = comp.compress(state, x)      # payload: pytree of small arrays
    x_hat = comp.decompress(payload, shape, dtype)
    comp.payload_bytes(shape, dtype)              # analytic wire bytes / message

The *payload* is exactly what crosses the slow link in the distributed
step (all-gathered over the worker axis), so its size is what shows up in
the dry-run HLO collective accounting.

Included compressors and the norm w.r.t. which they are contractive:
  Identity        alpha = 1            (any norm)
  TopK            Euclidean            (classical; B_2)
  RankK           spectral/Frobenius   (PowerSGD-style subspace iteration with
                                        Newton-Schulz orthonormalisation;
                                        approximately contractive, Remark 11)
  TopKSVD         any Schatten norm    (exact truncated SVD; §D Def. 10)
  ColumnTopK      mixed l_{p,q}        (§D Def. 13, p=2)
  Natural         elementwise, 8/9     (round to nearest power of two)
  RandomDropout   any norm, alpha=p    (§D Def. 9)
  Damping         any norm             (§D Def. 8; theoretical curiosity)
  WithNatural(C)  composes Natural onto the float leaves of C's payload
                  (the paper's TopK+Natural / RankK+Natural combos)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, ClassVar

import jax
import jax.numpy as jnp

from repro.kernels import (natural_compress, natural_decompress,
                           newton_schulz)

Payload = Any
State = Any


def _nelem(shape) -> int:
    return int(math.prod(shape)) if shape else 1


def _itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


@dataclass(frozen=True)
class Identity:
    """True identity (the paper's "ID").

    ``lossless_wire`` is the capability flag the EF algebra and the wire
    layout read (instead of sniffing type names, which breaks for
    subclasses): True means the payload must carry the *exact* f32
    difference — no wire-dtype quantisation — so EF21 with this
    compressor recovers uncompressed Gluon bit-for-bit. Inherited by
    subclasses; False (the default on every lossy compressor) keeps the
    wire cast inside C where the feedback loop corrects it.
    """
    name: str = "identity"
    lossless_wire: ClassVar[bool] = True

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        return x, state

    def decompress(self, payload, shape, dtype):
        return payload.astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        return _nelem(shape) * _itemsize(dtype)


@dataclass(frozen=True)
class Damping:
    """C(x) = gamma * x; contractive with alpha = 1-(1-gamma)^2 (§D Def. 8)."""
    gamma: float = 0.5

    @property
    def name(self):
        return f"damping{self.gamma}"

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        return (self.gamma * x.astype(jnp.float32)).astype(x.dtype), state

    def decompress(self, payload, shape, dtype):
        return payload.astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        return _nelem(shape) * _itemsize(dtype)


@dataclass(frozen=True)
class RandomDropout:
    """C(x) = x w.p. p else 0; contractive with alpha = p (§D Def. 9)."""
    p: float = 0.5

    @property
    def name(self):
        return f"dropout{self.p}"

    def init(self, key, shape, dtype) -> State:
        return {"key": key}

    def compress(self, state, x):
        key, sub = jax.random.split(state["key"])
        keep = jax.random.bernoulli(sub, self.p)
        payload = {"keep": keep, "x": jnp.where(keep, x, jnp.zeros_like(x))}
        return payload, {"key": key}

    def decompress(self, payload, shape, dtype):
        return payload["x"].astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        # expected wire cost: full message w.p. p, 1 bit otherwise
        return int(self.p * _nelem(shape) * _itemsize(dtype)) + 1


def _flat_topk(x: jax.Array, k: int):
    flat = x.reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


@dataclass(frozen=True)
class TopK:
    """Keep the k = ceil(fraction * n) largest-magnitude entries."""
    fraction: float = 0.1

    @property
    def name(self):
        return f"top{int(self.fraction * 100)}%"

    def k_for(self, shape) -> int:
        return max(1, int(math.ceil(self.fraction * _nelem(shape))))

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        vals, idx = _flat_topk(x, self.k_for(x.shape))
        return {"values": vals, "indices": idx}, state

    def decompress(self, payload, shape, dtype):
        flat = jnp.zeros((_nelem(shape),), dtype=payload["values"].dtype)
        flat = flat.at[payload["indices"]].set(payload["values"])
        return flat.reshape(shape).astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        k = self.k_for(shape)
        return k * (_itemsize(dtype) + 4)


@dataclass(frozen=True)
class ColumnTopK:
    """Keep the K columns with largest l2 norm (§D Def. 13, p=2).

    Contractive w.r.t. the mixed l_{2,q} norms (and Frobenius)."""
    fraction: float = 0.1

    @property
    def name(self):
        return f"coltop{int(self.fraction * 100)}%"

    def k_for(self, shape) -> int:
        return max(1, int(math.ceil(self.fraction * shape[-1])))

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        assert x.ndim == 2, "ColumnTopK expects a matrix"
        k = self.k_for(x.shape)
        colnorm = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=0)
        _, idx = jax.lax.top_k(colnorm, k)
        idx = idx.astype(jnp.int32)
        return {"cols": x[:, idx], "indices": idx}, state

    def decompress(self, payload, shape, dtype):
        out = jnp.zeros(shape, dtype=payload["cols"].dtype)
        out = out.at[:, payload["indices"]].set(payload["cols"])
        return out.astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        k = self.k_for(shape)
        return k * shape[0] * _itemsize(dtype) + 4 * k


@dataclass(frozen=True)
class RankK:
    """PowerSGD-style rank-K compression with Newton-Schulz
    orthonormalisation and warm-started sketches (TPU-native RankK).

    compress(x [m, n]):  P = x @ Q;  P <- ns_orth(P);  Qn = x^T @ P
    payload (P, Qn); decompress = P @ Qn^T. State keeps Q = Qn (warm start),
    so the subspace tracks the error-feedback residual across steps.
    Approximately contractive w.r.t. Frobenius/spectral norms (Remark 11).
    """
    fraction: float | None = None   # rank = ceil(fraction * min(m, n)) ...
    rank: int | None = None         # ... or a fixed rank

    @property
    def name(self):
        if self.rank is not None:
            return f"rank{self.rank}"
        return f"rank{int(self.fraction * 100)}%"

    def rank_for(self, shape) -> int:
        r_max = min(shape[-2], shape[-1])
        if self.rank is not None:
            return min(self.rank, r_max)
        return max(1, min(r_max, int(math.ceil(self.fraction * r_max))))

    def init(self, key, shape, dtype) -> State:
        assert len(shape) == 2, "RankK expects a matrix"
        r = self.rank_for(shape)
        q = jax.random.normal(key, (shape[1], r), dtype=jnp.float32)
        q = q / (jnp.linalg.norm(q, axis=0, keepdims=True) + 1e-12)
        return {"q": q.astype(dtype)}

    def compress(self, state, x):
        q = state["q"].astype(jnp.float32)
        xf = x.astype(jnp.float32)
        p = xf @ q
        p = newton_schulz(p, steps=5, use_pallas=False)  # orthonormal-ish cols
        qn = xf.T @ p
        payload = {"p": p.astype(x.dtype), "q": qn.astype(x.dtype)}
        return payload, {"q": qn.astype(state["q"].dtype)}

    def decompress(self, payload, shape, dtype):
        out = payload["p"].astype(jnp.float32) @ payload["q"].astype(jnp.float32).T
        return out.astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        r = self.rank_for(shape)
        return (shape[0] + shape[1]) * r * _itemsize(dtype)


@dataclass(frozen=True)
class TopKSVD:
    """Exact truncated SVD (§D Def. 10) — contractive for all Schatten
    norms. Reference implementation (CPU/tests; SVD is TPU-hostile)."""
    rank: int = 1

    @property
    def name(self):
        return f"svd{self.rank}"

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
        r = min(self.rank, s.shape[0])
        payload = {"us": u[:, :r] * s[None, :r], "vt": vt[:r, :]}
        return payload, state

    def decompress(self, payload, shape, dtype):
        return (payload["us"] @ payload["vt"]).astype(dtype)

    def payload_bytes(self, shape, dtype) -> int:
        r = self.rank
        return (shape[0] + shape[1]) * r * _itemsize(dtype)


@dataclass(frozen=True)
class Natural:
    """Round to nearest power of two; 9 bits/value on the wire.

    Elementwise relative error <= 1/3 => contractive with alpha = 8/9
    w.r.t. every absolute norm (Euclidean, l_inf, l1, Frobenius...)."""
    name: str = "natural"

    def init(self, key, shape, dtype) -> State:
        return {}

    def compress(self, state, x):
        codes, signs = natural_compress(x, use_pallas=False)
        return {"codes": codes, "signs": signs}, state

    def decompress(self, payload, shape, dtype):
        return natural_decompress(payload["codes"], payload["signs"],
                                  shape, dtype)

    def payload_bytes(self, shape, dtype) -> int:
        n = _nelem(shape)
        return n + (n + 7) // 8  # 9 bits / value


@dataclass(frozen=True)
class WithNatural:
    """Compose Natural onto the float leaves of an inner compressor's
    payload (the paper's TopK+Natural and RankK+Natural combos).

    jit-safe: the float-leaf shapes are reconstructed statically from the
    original array shape, so payloads stay fixed-shape pytrees of arrays.

    ``WithNatural(Identity)`` is supported end-to-end (compress,
    decompress and payload_bytes agree): the inner payload IS the array,
    so it Natural-compresses the whole message — semantically Natural,
    kept for composition symmetry. Quantisation makes the wrapper lossy
    regardless of the inner compressor (``lossless_wire = False``).
    """
    inner: Any
    lossless_wire: ClassVar[bool] = False

    @property
    def name(self):
        return f"{self.inner.name}+natural"

    def init(self, key, shape, dtype) -> State:
        return self.inner.init(key, shape, dtype)

    def _float_leaf_shapes(self, shape) -> dict[str, tuple[int, ...]]:
        """Float leaves of a dict payload (Identity's bare-array payload
        is handled directly in compress/decompress, consistent with the
        Identity branch of payload_bytes)."""
        if isinstance(self.inner, TopK):
            return {"values": (self.inner.k_for(shape),)}
        if isinstance(self.inner, RankK):
            r = self.inner.rank_for(shape)
            return {"p": (shape[0], r), "q": (shape[1], r)}
        if isinstance(self.inner, TopKSVD):
            r = self.inner.rank
            return {"us": (shape[0], r), "vt": (r, shape[1])}
        raise TypeError(f"WithNatural does not support {type(self.inner)}")

    def compress(self, state, x):
        payload, state = self.inner.compress(state, x)
        if isinstance(self.inner, Identity):
            codes, signs = natural_compress(payload, use_pallas=False)
            return {"codes": codes, "signs": signs}, state
        out = dict(payload)
        for name in self._float_leaf_shapes(x.shape):
            codes, signs = natural_compress(payload[name], use_pallas=False)
            out[name + "_codes"] = codes
            out[name + "_signs"] = signs
            del out[name]
        return out, state

    def decompress(self, payload, shape, dtype):
        if isinstance(self.inner, Identity):
            return self.inner.decompress(natural_decompress(
                payload["codes"], payload["signs"], shape, jnp.bfloat16),
                shape, dtype)
        inner_payload = dict(payload)
        for name, lshape in self._float_leaf_shapes(shape).items():
            inner_payload[name] = natural_decompress(
                payload[name + "_codes"], payload[name + "_signs"],
                lshape, jnp.bfloat16)
            del inner_payload[name + "_codes"]
            del inner_payload[name + "_signs"]
        return self.inner.decompress(inner_payload, shape, dtype)

    def payload_bytes(self, shape, dtype) -> int:
        inner_b = self.inner.payload_bytes(shape, dtype)
        # float portion shrinks to 9/ (8*itemsize); int indices unchanged.
        # Recompute precisely per inner type:
        if isinstance(self.inner, TopK):
            k = self.inner.k_for(shape)
            return k * 4 + k + (k + 7) // 8
        if isinstance(self.inner, (RankK, TopKSVD)):
            r = self.inner.rank_for(shape) if isinstance(self.inner, RankK) else self.inner.rank
            n = (shape[0] + shape[1]) * r
            return n + (n + 7) // 8
        if isinstance(self.inner, Identity):
            n = _nelem(shape)
            return n + (n + 7) // 8
        return inner_b  # fallback: no extra savings accounted

    # expose for RankK state compat
    def rank_for(self, shape):
        return self.inner.rank_for(shape)


def empirical_alpha(comp, key, x, n_trials: int = 8, norm_kind: str = "frobenius") -> float:
    """Estimate the contractivity parameter alpha = 1 - E||C(x)-x||^2/||x||^2."""
    from .norms import norm as _norm
    state = comp.init(key, x.shape, x.dtype)
    num = 0.0
    for i in range(n_trials):
        payload, state = comp.compress(state, x)
        xh = comp.decompress(payload, x.shape, jnp.float32)
        num += float(_norm(xh - x.astype(jnp.float32), norm_kind) ** 2)
    den = float(_norm(x, norm_kind) ** 2)
    return 1.0 - num / (n_trials * den)


REGISTRY = {
    "identity": lambda: Identity(),
    "natural": lambda: Natural(),
    "identity+natural": lambda: WithNatural(Identity()),
    "top5": lambda: TopK(0.05),
    "top10": lambda: TopK(0.10),
    "top15": lambda: TopK(0.15),
    "top20": lambda: TopK(0.20),
    "top10+natural": lambda: WithNatural(TopK(0.10)),
    "top15+natural": lambda: WithNatural(TopK(0.15)),
    "rank5": lambda: RankK(fraction=0.05),
    "rank10": lambda: RankK(fraction=0.10),
    "rank15": lambda: RankK(fraction=0.15),
    "rank20": lambda: RankK(fraction=0.20),
    "rank10+natural": lambda: WithNatural(RankK(fraction=0.10)),
    "rank15+natural": lambda: WithNatural(RankK(fraction=0.15)),
}


def get_compressor(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown compressor '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]()
