"""Minimal AdamW — the baseline optimizer the paper's family replaces,
and the conventional choice for non-hidden layers in Muon deployments
(paper footnote 2). Pure functional, optax-compatible shape."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def adamw_init(params: Any) -> dict:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(z, params),
            "nu": jax.tree.map(z, params)}


def adamw_update(params: Any, grads: Any, state: dict, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> tuple[Any, dict]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["nu"], grads)
    lr = jnp.asarray(lr, jnp.float32)

    def upd(p, m, v):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        step_ = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay
                      * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step_).astype(p.dtype)

    return jax.tree.map(upd, params, mu, nu), {"step": step, "mu": mu, "nu": nu}
