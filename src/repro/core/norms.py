"""Norms and dual norms on matrix/vector spaces (paper §1.1, §B).

Each norm is identified by a string key. For every primal norm we expose
its dual (`DUAL[key]`) and a numerical evaluator. Spectral/nuclear duality,
l1/linf duality, and Frobenius self-duality are the cases used by the
LMO-based optimizers (Muon = spectral, Scion embeddings = linf, Gluon =
arbitrary per-layer choice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# primal -> dual
DUAL = {
    "spectral": "nuclear",
    "nuclear": "spectral",
    "frobenius": "frobenius",
    "linf": "l1",
    "l1": "linf",
    "col_l2": "col_l2_dual",      # ||X||_{1->2}: max column l2; dual = sum of column l2
    "col_l2_dual": "col_l2",
    "row_l2": "row_l2_dual",      # ||X||_{2->inf}-ish: max row l2; dual = sum of row l2
    "row_l2_dual": "row_l2",
}


def _svals(x: jax.Array) -> jax.Array:
    return jnp.linalg.svd(x.reshape(x.shape[0], -1) if x.ndim > 2 else x,
                          compute_uv=False)


def norm(x: jax.Array, kind: str) -> jax.Array:
    """Evaluate ||x||_kind. 1-D inputs treat vector norms; matrix norms
    require 2-D input (higher-rank inputs are flattened to 2-D on the
    trailing axes for spectral/nuclear)."""
    if kind == "frobenius":
        return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    if kind == "linf":
        return jnp.max(jnp.abs(x))
    if kind == "l1":
        return jnp.sum(jnp.abs(x))
    if kind == "spectral":
        if x.ndim < 2:
            return jnp.max(jnp.abs(x))
        return jnp.max(_svals(x.astype(jnp.float32)))
    if kind == "nuclear":
        if x.ndim < 2:
            return jnp.sum(jnp.abs(x))
        return jnp.sum(_svals(x.astype(jnp.float32)))
    if kind == "col_l2":
        # operator norm l1 -> l2 : max over columns of column l2 norm
        x2 = x.astype(jnp.float32)
        return jnp.max(jnp.sqrt(jnp.sum(jnp.square(x2), axis=0)))
    if kind == "col_l2_dual":
        x2 = x.astype(jnp.float32)
        return jnp.sum(jnp.sqrt(jnp.sum(jnp.square(x2), axis=0)))
    if kind == "row_l2":
        x2 = x.astype(jnp.float32)
        return jnp.max(jnp.sqrt(jnp.sum(jnp.square(x2), axis=1)))
    if kind == "row_l2_dual":
        x2 = x.astype(jnp.float32)
        return jnp.sum(jnp.sqrt(jnp.sum(jnp.square(x2), axis=1)))
    raise ValueError(f"unknown norm kind: {kind}")


def dual_norm(x: jax.Array, kind: str) -> jax.Array:
    """||x||_* where * is the dual of `kind`."""
    return norm(x, DUAL[kind])


def norm_equivalence_constants(shape: tuple[int, ...], kind: str) -> tuple[float, float]:
    """(rho_lo, rho_hi) with rho_lo * ||X||_kind <= ||X||_2 <= rho_hi * ||X||_kind.

    Used by the theory-facing diagnostics (Remark 7: spectral has
    rho_lo = 1, rho_hi = sqrt(rank))."""
    import math
    n = 1
    for s in shape:
        n *= s
    if kind == "frobenius":
        return 1.0, 1.0
    if kind == "spectral":
        r = min(shape) if len(shape) >= 2 else 1
        return 1.0, math.sqrt(r)
    if kind == "linf":
        return 1.0, math.sqrt(n)
    if kind == "l1":
        return 1.0 / math.sqrt(n), 1.0
    if kind == "col_l2":
        c = shape[-1] if len(shape) >= 2 else 1
        return 1.0, math.sqrt(c)
    if kind == "row_l2":
        r = shape[0] if len(shape) >= 2 else 1
        return 1.0, math.sqrt(r)
    raise ValueError(f"no equivalence constants for {kind}")
