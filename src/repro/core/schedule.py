"""Radius / learning-rate schedules. The paper adopts Karpathy's nanoGPT
scheduler (linear warmup + decay); we also provide the theory-facing
constant-over-sqrt(K) radius of Theorems 4/17 and cosine/WSD variants."""
from __future__ import annotations

import jax.numpy as jnp


def constant(t0: float):
    return lambda step: jnp.asarray(t0, jnp.float32)


def theory_radius(eta: float, total_steps: int):
    """t^k = eta / sqrt(K+1) — problem-constant-free radii (Thm 4/17)."""
    val = eta / (total_steps + 1) ** 0.5
    return lambda step: jnp.asarray(val, jnp.float32)


def warmup_linear_decay(t0: float, warmup: int, total: int,
                        final_frac: float = 0.1):
    """nanoGPT-style: linear warmup then linear decay to final_frac * t0."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.asarray(max(warmup, 1), jnp.float32)
        warm = step / w
        frac = jnp.clip((step - w) / max(total - warmup, 1), 0.0, 1.0)
        decay = 1.0 - (1.0 - final_frac) * frac
        return t0 * jnp.where(step < w, warm, decay)
    return fn


def cosine(t0: float, warmup: int, total: int, final_frac: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.asarray(max(warmup, 1), jnp.float32)
        warm = step / w
        prog = jnp.clip((step - w) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return t0 * jnp.where(step < w, warm, cos)
    return fn
