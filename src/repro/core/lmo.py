"""Linear minimization oracles over norm balls and sharp operators (§2, §C).

Conventions (paper eq. (2) and §C):
  * ``lmo_direction(g, kind)`` returns Z* = LMO_{B(0,1)}(g)
    = argmin_{||Z|| <= 1} <g, Z>, so <g, Z*> = -||g||_* and ||Z*|| = 1.
  * ``sharp(g, kind)`` returns g# = -||g||_* * lmo_direction(g)
    (the sharp operator; <g, g#> = ||g#||^2 and ||g||_* = ||g#||).
  * the optimizer step is X <- X + t * lmo_direction(G), i.e.
    X <- LMO_{B(X, t)}(G).

Norm kinds:
  spectral   : spectral-norm ball; Z* = -UV^T via Newton-Schulz (Muon).
  sign       : l_inf ball; Z* = -sign(g) (Scion embeddings / 1-D params).
  col_l2     : ball of max-column-l2 norm (||.||_{1->2}); per-column
               normalised direction (Gluon column-wise variant).
  row_l2     : ball of max-row-l2 norm; per-row normalised direction.
  euclid     : Frobenius/l2 ball; Z* = -g/||g||_F (normalised SGD).
  nuclear    : nuclear-norm ball; Z* = -u1 v1^T (rank-1, power iteration).
               Doubles as the paper's §D.1 "LMO as compressor" example.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import newton_schulz, newton_schulz_batched

EPS = 1e-12

SUPPORTED = ("spectral", "sign", "col_l2", "row_l2", "euclid", "nuclear")

# LMO kind -> the norm whose unit ball it minimises over
BALL_NORM = {"spectral": "spectral", "sign": "linf", "euclid": "frobenius",
             "col_l2": "col_l2", "row_l2": "row_l2", "nuclear": "nuclear"}


def _power_iteration_rank1(g: jax.Array, iters: int = 12) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top singular triple (sigma, u, v) of a 2-D matrix by power iteration
    (deterministic start: leading row-sum vector)."""
    gf = g.astype(jnp.float32)
    v = jnp.sum(jnp.abs(gf), axis=0) + 1e-3
    v = v / (jnp.linalg.norm(v) + EPS)

    def body(v, _):
        u = gf @ v
        u = u / (jnp.linalg.norm(u) + EPS)
        v = gf.T @ u
        s = jnp.linalg.norm(v)
        v = v / (s + EPS)
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    u = gf @ v
    s = jnp.linalg.norm(u)
    u = u / (s + EPS)
    return s, u, v


def lmo_direction(g: jax.Array, kind: str, *, ns_steps: int = 5,
                  use_pallas: str | bool = "auto") -> jax.Array:
    """Z* = argmin_{||Z||_kind <= 1} <g, Z>."""
    if kind == "spectral":
        if g.ndim != 2:
            raise ValueError("spectral LMO needs a 2-D matrix")
        return -newton_schulz(g, steps=ns_steps, use_pallas=use_pallas)
    if kind == "sign":
        return -jnp.sign(g)
    if kind == "euclid":
        gf = g.astype(jnp.float32)
        return (-gf / (jnp.linalg.norm(gf) + EPS)).astype(g.dtype)
    if kind == "col_l2":
        gf = g.astype(jnp.float32)
        col = jnp.sqrt(jnp.sum(jnp.square(gf), axis=0, keepdims=True))
        return (-gf / (col + EPS)).astype(g.dtype)
    if kind == "row_l2":
        gf = g.astype(jnp.float32)
        row = jnp.sqrt(jnp.sum(jnp.square(gf), axis=1, keepdims=True))
        return (-gf / (row + EPS)).astype(g.dtype)
    if kind == "nuclear":
        s, u, v = _power_iteration_rank1(g)
        return (-jnp.outer(u, v)).astype(g.dtype)
    raise ValueError(f"unknown LMO kind: {kind}")


def lmo_direction_batched(g: jax.Array, kind: str = "spectral", *,
                          ns_steps: int = 5,
                          use_pallas: str | bool = "auto",
                          mesh=None, pspec=None) -> jax.Array:
    """Batched Z* over a ``[B, m, n]`` canonical slice stack (m <= n,
    orientation fixed upstream by ``repro.dist.bucketing``).

    Spectral only — the one LMO whose per-slice cost (a Newton-Schulz
    chain) warrants bucketed dispatch (DESIGN.md §7); every other kind is
    elementwise and fuses trivially. Bit-equal per slice to
    ``lmo_direction(slice, "spectral")`` on the jnp path.

    ``mesh``/``pspec`` (the bucket's ``ns_bucket_pspec``) thread the
    sharding constraint through the whole Newton-Schulz chain so the
    batched dispatch runs sharded instead of replicated — a value
    identity either way.
    """
    if kind != "spectral":
        raise ValueError(f"batched LMO supports 'spectral' only, got {kind}")
    if g.ndim != 3:
        raise ValueError("batched spectral LMO needs a [B, m, n] stack")
    return -newton_schulz_batched(g, steps=ns_steps, use_pallas=use_pallas,
                                  mesh=mesh, pspec=pspec)


def sharp(g: jax.Array, kind: str, **kw) -> jax.Array:
    """g# = argmax_X {<g, X> - ||X||^2/2} = -||g||_* LMO_{B(0,1)}(g)."""
    from .norms import dual_norm
    d = lmo_direction(g, kind, **kw)
    return (-dual_norm(g, BALL_NORM[kind])
            * d.astype(jnp.float32)).astype(g.dtype)


def lmo_step(x: jax.Array, g: jax.Array, radius: jax.Array | float,
             kind: str, **kw) -> jax.Array:
    """X^{k+1} = LMO_{B(X^k, t)}(G^k) = X^k + t * LMO_{B(0,1)}(G^k)."""
    d = lmo_direction(g, kind, **kw)
    return (x.astype(jnp.float32)
            + jnp.asarray(radius, jnp.float32) * d.astype(jnp.float32)
            ).astype(x.dtype)


def default_radius_scale(shape: tuple[int, ...], kind: str) -> float:
    """Muon-style per-layer radius scaling: sqrt(max(1, out/in)) for
    spectral matrices (out = shape[-1] fan-out in our [in, out] layout),
    1.0 otherwise."""
    if kind == "spectral" and len(shape) == 2:
        fan_in, fan_out = shape[0], shape[1]
        return max(1.0, fan_out / max(fan_in, 1)) ** 0.5
    return 1.0
