"""EF21 (w2s) and EF21-P (s2w) error-feedback algebra (§2, §A.2).

Both mechanisms share one primitive: maintain an estimate E of a target T,
transmit the compressed difference C(T - E), and advance E by the *exact
decompressed* message, so sender and receiver stay bit-identical:

    payload = C(T - E);   E' = E + decompress(payload)

EF21   : E = G_j (worker gradient estimator), T = M_j (momentum).
EF21-P : E = W   (worker model estimate),     T = X^{k+1} (server iterate).

The wire dtype is bf16: the cast is *inside* C, so the quantisation error
is part of the compression error the feedback loop corrects.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def ef_compress_step(comp, comp_state: Any, estimate: jax.Array,
                     target: jax.Array,
                     wire_dtype=jnp.bfloat16) -> tuple[Any, Any, jax.Array]:
    """One error-feedback round on a single tensor.

    Returns (payload, new_comp_state, new_estimate) with
    new_estimate = estimate + decompress(payload) in f32.
    """
    diff = (target.astype(jnp.float32) - estimate.astype(jnp.float32))
    # Lossless compressors (the paper's "ID" and subclasses) carry the
    # exact f32 difference — capability flag, not a type-name check, so
    # Identity subclasses stay lossless and WithNatural(Identity) does
    # not (the Natural wrapper quantises).
    if getattr(comp, "lossless_wire", False):
        wire_dtype = jnp.float32
    payload, comp_state = comp.compress(comp_state, diff.astype(wire_dtype))
    delta = comp.decompress(payload, diff.shape, jnp.float32)
    new_estimate = (estimate.astype(jnp.float32) + delta).astype(estimate.dtype)
    return payload, comp_state, new_estimate


def apply_payload(comp, payload, estimate: jax.Array) -> jax.Array:
    """Receiver side: E' = E + decompress(payload)."""
    delta = comp.decompress(payload, estimate.shape, jnp.float32)
    return (estimate.astype(jnp.float32) + delta).astype(estimate.dtype)
