"""EF21-Muon — the paper's contribution (Algorithms 1-3) as a composable
JAX optimizer.

Layer-wise by construction: every parameter leaf i carries a ParamMeta
(norm kind for its LMO, radius scale, stack depth), its own worker
compressors C_{i,j} and server compressor C_i, matching Algorithm 3.

The optimizer *owns* gradient evaluation (workers differentiate at their
model estimate W, not at X), so the API takes a grad function:

    opt   = EF21Muon(cfg)
    state = opt.init(key, params, metas)
    state, aux = opt.step(state, grad_and_loss, batch, t)

where ``grad_and_loss(params, batch_slice) -> (loss, grads)`` and ``batch``
has a leading worker dimension of size cfg.n_workers. Per-worker gradients
are computed with ``jax.vmap(..., in_axes=(None, 0))`` — workers stay
computationally independent, so the only cross-worker traffic in the
lowered HLO is the all-gather of compressed payloads (hooked via
``reshard_payloads`` by the distributed trainer).

Special cases recovered exactly (tested):
  * w2s = s2w = identity, n_workers = 1  ==> Gluon (=> Muon for spectral
    norms, Scion for spectral+sign maps).
  * beta = 1.0  ==> the deterministic Algorithm 2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import compressors as comp_lib
from .compressors import get_compressor
from .error_feedback import ef_compress_step
from .lmo import default_radius_scale, lmo_direction


@dataclass(frozen=True)
class ParamMeta:
    """Per-leaf optimizer metadata (the 'layer' of the layer-wise method)."""
    lmo: str = "spectral"          # norm kind for the LMO step
    radius_scale: float = 1.0      # per-layer radius multiplier t_i = scale * t
    stack_dims: int = 0            # leading dims that stack independent layers
    compressible: bool = True      # False => identity w2s compressor (tiny leaves)


def meta_like(x: jax.Array, path: str = "") -> ParamMeta:
    """Heuristic meta: 2-D matrices -> spectral (Muon), everything else ->
    sign (Scion's l_inf), embeddings/unembeddings -> sign."""
    shape = x.shape
    name = path.lower()
    stack = 0
    core = shape
    # stacked layers [L, ...] / experts [L, E, ...] are detected by models;
    # heuristic only handles unstacked leaves.
    if len(core) == 2 and not any(k in name for k in ("embed", "unembed", "lm_head")):
        return ParamMeta("spectral", default_radius_scale(core, "spectral"), stack)
    return ParamMeta("sign", 1.0, stack)


@dataclass(frozen=True)
class EF21MuonConfig:
    n_workers: int = 1
    beta: float = 0.1              # gradient weight: M = (1-beta) M + beta g
    w2s: str = "identity"          # worker->server compressor (C_D)
    s2w: str = "identity"          # server->worker compressor (C_P, EF21-P)
    ns_steps: int = 5
    use_pallas: Any = "auto"
    wire_dtype: Any = jnp.bfloat16
    state_dtype: Any = jnp.float32


def _slice_shape(shape: tuple[int, ...], stack_dims: int) -> tuple[int, ...]:
    return tuple(shape[stack_dims:])


def _resolve_compressor(name: str, slice_shape: tuple[int, ...]):
    """Pick a compatible compressor for this leaf: rank-type compressors
    need matrices; fall back to Natural for vectors (tiny anyway)."""
    comp = get_compressor(name)
    needs_2d = isinstance(comp, comp_lib.RankK) or (
        isinstance(comp, comp_lib.WithNatural)
        and isinstance(comp.inner, (comp_lib.RankK, comp_lib.TopKSVD)))
    if needs_2d and len(slice_shape) != 2:
        return get_compressor("natural") if "natural" in name else comp_lib.TopK(0.25)
    return comp


def _vmap_n(fn, n: int):
    for _ in range(n):
        fn = jax.vmap(fn)
    return fn


class EF21Muon:
    def __init__(self, cfg: EF21MuonConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, params: Any, metas: Any) -> dict:
        cfg = self.cfg
        sd = cfg.state_dtype
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, sd), params)
        g_w = jax.tree.map(
            lambda p: jnp.zeros((cfg.n_workers,) + p.shape, sd), params)
        m_w = None if cfg.beta >= 1.0 else jax.tree.map(
            lambda p: jnp.zeros((cfg.n_workers,) + p.shape, sd), params)

        leaves, treedef = jax.tree.flatten(params)
        metas_l = treedef.flatten_up_to(metas)
        keys = jax.random.split(key, len(leaves) * (cfg.n_workers + 1))

        cw_states, cs_states = [], []
        for i, (p, m) in enumerate(zip(leaves, metas_l)):
            sshape = _slice_shape(p.shape, m.stack_dims)
            wname = cfg.w2s if m.compressible else "identity"
            wcomp = _resolve_compressor(wname, sshape)
            scomp = _resolve_compressor(cfg.s2w if m.compressible else "identity", sshape)

            def init_one(k, comp=wcomp, sshape=sshape):
                return comp.init(k, sshape, jnp.dtype(cfg.wire_dtype))

            stack = p.shape[:m.stack_dims]
            n_stack = int(math.prod(stack)) if stack else 1
            wkeys = jax.random.split(keys[i], cfg.n_workers * n_stack).reshape(
                (cfg.n_workers,) + stack)
            cw = _vmap_n(init_one, m.stack_dims + 1)(wkeys)
            skeys = jax.random.split(keys[len(leaves) + i], max(n_stack, 1)
                                     ).reshape(stack) \
                if stack else keys[len(leaves) + i]
            cs = _vmap_n(lambda k, comp=scomp, sshape=sshape: comp.init(
                k, sshape, jnp.dtype(cfg.wire_dtype)), m.stack_dims)(skeys)
            cw_states.append(cw)
            cs_states.append(cs)

        state = {
            "step": jnp.zeros((), jnp.int32),
            "x": params,
            "g_server": zeros,
            "g_w": g_w,
            "m_w": m_w,
            "cw_state": treedef.unflatten(cw_states),
        }
        if cfg.s2w != "identity":
            state["w"] = jax.tree.map(lambda p: p.astype(sd), params)
            state["cs_state"] = treedef.unflatten(cs_states)
        return state

    # ------------------------------------------------------------ bookkeeping
    def w2s_bytes_per_worker(self, params: Any, metas: Any) -> int:
        """Static wire cost of one worker->server message (Table 2)."""
        cfg = self.cfg
        total = 0
        for p, m in zip(jax.tree.leaves(params),
                        jax.tree.flatten(params)[1].flatten_up_to(metas)):
            sshape = _slice_shape(p.shape, m.stack_dims)
            comp = _resolve_compressor(cfg.w2s if m.compressible else "identity",
                                       sshape)
            n_stack = int(math.prod(p.shape[:m.stack_dims])) if m.stack_dims else 1
            total += n_stack * comp.payload_bytes(sshape, cfg.wire_dtype)
        return total

    def dense_bytes(self, params: Any) -> int:
        return sum(int(math.prod(p.shape)) * jnp.dtype(self.cfg.wire_dtype).itemsize
                   for p in jax.tree.leaves(params))

    # The jit-friendly entry point: metas are static, so we build the step
    # function once per (metas, shapes) and let the caller jit it.
    def make_step(self, metas: Any,
                  reshard_payloads: Callable = lambda tree: tree,
                  donate: bool = False) -> Callable:
        cfg = self.cfg

        def step(state: dict, grad_and_loss: Callable, batch: Any,
                 t: jax.Array | float) -> tuple[dict, dict]:
            treedef = jax.tree.structure(state["x"])
            metas_l = treedef.flatten_up_to(metas)

            # ---- 1. EF21-P: workers' model estimate W
            if cfg.s2w != "identity":
                x_l = treedef.flatten_up_to(state["x"])
                w_l = treedef.flatten_up_to(state["w"])
                cs_l = treedef.flatten_up_to(state["cs_state"])
                new_w, new_cs = [], []
                for x, w, cs, m in zip(x_l, w_l, cs_l, metas_l):
                    sshape = _slice_shape(x.shape, m.stack_dims)
                    comp = _resolve_compressor(
                        cfg.s2w if m.compressible else "identity", sshape)

                    def one(cs, w, x, comp=comp):
                        _, cs2, w2 = ef_compress_step(comp, cs, w, x,
                                                      cfg.wire_dtype)
                        return cs2, w2

                    cs2, w2 = _vmap_n(one, m.stack_dims)(cs, w, x)
                    new_w.append(w2)
                    new_cs.append(cs2)
                w_tree = treedef.unflatten(new_w)
                cs_tree = treedef.unflatten(new_cs)
            else:
                w_tree = state["x"]
                cs_tree = None

            # ---- 2. per-worker stochastic gradients at W (no cross-worker comm)
            w_cast = jax.tree.map(
                lambda w, x: w.astype(x.dtype), w_tree, state["x"])
            losses, grads = jax.vmap(grad_and_loss, in_axes=(None, 0))(
                w_cast, batch)

            # ---- 3. momentum + EF21 per worker, layer-wise
            beta = cfg.beta
            if state["m_w"] is not None:
                m_new = jax.tree.map(
                    lambda m, g: ((1.0 - beta) * m.astype(jnp.float32)
                                  + beta * g.astype(jnp.float32)
                                  ).astype(m.dtype),
                    state["m_w"], grads)
            else:
                m_new = jax.tree.map(
                    lambda g: g.astype(cfg.state_dtype), grads)

            g_w_l = treedef.flatten_up_to(state["g_w"])
            m_l = treedef.flatten_up_to(m_new)
            cw_l = treedef.flatten_up_to(state["cw_state"])

            payloads, new_gw, new_cw = [], [], []
            for gw, m, cw, meta in zip(g_w_l, m_l, cw_l, metas_l):
                sshape = _slice_shape(gw.shape[1:], meta.stack_dims)
                comp = _resolve_compressor(
                    cfg.w2s if meta.compressible else "identity", sshape)

                def one(cw, gw, m, comp=comp):
                    payload, cw2, gw2 = ef_compress_step(comp, cw, gw, m,
                                                         cfg.wire_dtype)
                    return payload, cw2, gw2

                payload, cw2, gw2 = _vmap_n(one, meta.stack_dims + 1)(cw, gw, m)
                payloads.append(payload)
                new_gw.append(gw2)
                new_cw.append(cw2)

            # ---- 4. "server" receives payloads: gather across the worker
            # axis (trainer supplies the resharding hook), decompress, average.
            payloads = reshard_payloads(payloads)
            g_s_l = treedef.flatten_up_to(state["g_server"])
            new_gs = []
            for gs, payload, meta in zip(g_s_l, payloads, metas_l):
                sshape = _slice_shape(gs.shape, meta.stack_dims)
                comp = _resolve_compressor(
                    cfg.w2s if meta.compressible else "identity", sshape)

                def dec(payload, comp=comp, sshape=sshape):
                    return comp.decompress(payload, sshape, jnp.float32)

                deltas = _vmap_n(dec, meta.stack_dims + 1)(payload)
                new_gs.append((gs.astype(jnp.float32)
                               + jnp.mean(deltas, axis=0)).astype(gs.dtype))

            # ---- 5. layer-wise LMO step on the server iterate
            x_l = treedef.flatten_up_to(state["x"])
            new_x = []
            for x, gs, meta in zip(x_l, new_gs, metas_l):
                radius = jnp.asarray(t, jnp.float32) * meta.radius_scale

                def upd(x, g, meta=meta, radius=radius):
                    d = lmo_direction(g, meta.lmo, ns_steps=cfg.ns_steps,
                                      use_pallas=cfg.use_pallas)
                    return (x.astype(jnp.float32)
                            + radius * d.astype(jnp.float32)).astype(x.dtype)

                new_x.append(_vmap_n(upd, meta.stack_dims)(x, gs))

            new_state = {
                "step": state["step"] + 1,
                "x": treedef.unflatten(new_x),
                "g_server": treedef.unflatten(new_gs),
                "g_w": treedef.unflatten(new_gw),
                "m_w": m_new if state["m_w"] is not None else None,
                "cw_state": treedef.unflatten(new_cw),
            }
            if cfg.s2w != "identity":
                new_state["w"] = w_tree
                new_state["cs_state"] = cs_tree
            aux = {"loss": jnp.mean(losses),
                   "grad_est_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in new_gs))}
            return new_state, aux

        return step
