"""EF21-Muon — the paper's contribution (Algorithms 1-3) as a composable
JAX optimizer.

Layer-wise by construction: every parameter leaf i carries a ParamMeta
(norm kind for its LMO, radius scale, stack depth), its own worker
compressors C_{i,j} and server compressor C_i, matching Algorithm 3. All
per-leaf mechanics (slice shapes, compressor resolution, stack vmaps)
live in one ``repro.dist.layerwise.LayerPlan`` built once per
(treedef, metas, shapes); the phases below state algorithm steps only.

The optimizer *owns* gradient evaluation (workers differentiate at their
model estimate W, not at X), so the API takes a grad function:

    opt   = EF21Muon(cfg)
    state = opt.init(key, params, metas)
    state, aux = opt.step(state, grad_and_loss, batch, t)

where ``grad_and_loss(params, batch_slice) -> (loss, grads)`` and ``batch``
has a leading worker dimension of size cfg.n_workers. Per-worker gradients
are computed with ``jax.vmap(..., in_axes=(None, 0))`` — workers stay
computationally independent, so the only cross-worker traffic in the
lowered HLO is the all-gather of compressed payloads (hooked via
``reshard_payloads`` by the distributed trainer).

Special cases recovered exactly (tested):
  * w2s = s2w = identity, n_workers = 1  ==> Gluon (=> Muon for spectral
    norms, Scion for spectral+sign maps).
  * beta = 1.0  ==> the deterministic Algorithm 2.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.layerwise import LayerPlan, dense_payload_bytes, vmap_n
from repro.dist.participation import (mask_bcast, participation_mask,
                                      payload_finite_mask, reception_mask,
                                      validate_spec)
from repro.dist.pipeline import s2w_issue_order
from repro.dist.resync import (init_resync_state, replay_masks,
                               resolve_ring_depth, ring_push)
from repro.obs.metrics import (MetricSet, leaf_names, orth_residual,
                               rel_error, worker_mean_norm)
from repro.obs.trace import PHASE_SPANS, phase_span, wire_stage_span

from .error_feedback import apply_payload, ef_compress_step
from .lmo import default_radius_scale, lmo_direction, lmo_direction_batched


@dataclass(frozen=True)
class ParamMeta:
    """Per-leaf optimizer metadata (the 'layer' of the layer-wise method)."""
    lmo: str = "spectral"          # norm kind for the LMO step
    radius_scale: float = 1.0      # per-layer radius multiplier t_i = scale * t
    stack_dims: int = 0            # leading dims that stack independent layers
    compressible: bool = True      # False => identity w2s compressor (tiny leaves)


def meta_like(x: jax.Array, path: str = "") -> ParamMeta:
    """Heuristic meta: 2-D matrices -> spectral (Muon), everything else ->
    sign (Scion's l_inf), embeddings/unembeddings -> sign."""
    shape = x.shape
    name = path.lower()
    stack = 0
    core = shape
    # stacked layers [L, ...] / experts [L, E, ...] are detected by models;
    # heuristic only handles unstacked leaves.
    if len(core) == 2 and not any(k in name for k in ("embed", "unembed", "lm_head")):
        return ParamMeta("spectral", default_radius_scale(core, "spectral"), stack)
    return ParamMeta("sign", 1.0, stack)


@dataclass(frozen=True)
class EF21MuonConfig:
    n_workers: int = 1
    beta: float = 0.1              # gradient weight: M = (1-beta) M + beta g
    w2s: str = "identity"          # worker->server compressor (C_D)
    s2w: str = "identity"          # server->worker compressor (C_P, EF21-P)
    ns_steps: int = 5
    use_pallas: Any = "auto"
    wire_dtype: Any = jnp.bfloat16
    state_dtype: Any = jnp.float32
    wire_pack: bool = True         # fuse payloads into one uint8 wire buffer
    ns_bucketing: bool = True      # batch spectral LMOs by shape bucket (§7)
    wire_stages: Any = "auto"      # staged wire pipeline (§8): "auto" = one
                                   # stage per NS bucket + the eager chunk;
                                   # 1 = the monolithic single-gather path
                                   # (bit-identical A/B arm); N caps stages
    wire_pack_s2w: Any = "auto"    # pack the EF21-P server->worker model
                                   # update through the s2w wire leg (§9):
                                   # "auto" follows wire_pack; False keeps
                                   # the unpacked phase-1 path (the value-
                                   # bit-equal A/B arm); True forces it
    metrics: bool = False          # collect the in-graph MetricSet (§10):
                                   # per-leaf EF21 error/momentum norms,
                                   # compression rel. error, NS residual,
                                   # wire bytes — returned in
                                   # aux["metrics"], no host sync. Off =>
                                   # the step lowers identically (the
                                   # metric reads never feed the update,
                                   # so the on arm is value-bit-equal)
    trace_spans: bool = False      # jax.named_scope the five phases and
                                   # every staged wire collective (§10)
                                   # so xprof shows the §8 overlap by
                                   # name; off => no op-metadata change
                                   # (host TraceAnnotations are always
                                   # on — they never touch the lowering)
    participation: Any = "full"    # elastic worker participation (§11):
                                   # "full" (every worker — takes the
                                   # exact pre-§11 code path, lowering-
                                   # identical), "bernoulli(p)",
                                   # "round_robin(k)", or a
                                   # dist.participation.Explicit mask
                                   # table. Absent workers' EF21 error/
                                   # momentum/compressor state freezes
                                   # and the server fold normalises by
                                   # the dynamic participant count; the
                                   # wire collectives keep their static
                                   # shapes (masked at fold time)
    participation_seed: int = 0    # seeds bernoulli schedules; the
                                   # history is deterministic in
                                   # (spec, seed, step) => resume-stable
    nonfinite_guard: bool = False  # per-worker payload finiteness check
                                   # (§11): a worker whose payload
                                   # carries NaN/Inf is demoted to non-
                                   # participating for the step; all-
                                   # poisoned steps fall back to a
                                   # global skip (X frozen). Forced on
                                   # whenever a FaultPlan is passed to
                                   # make_step
    resync: Any = None             # desynchronized-worker rejoin (§13):
                                   # None/0 compiles the subsystem out
                                   # (the default, lowering-identical
                                   # arm); an int R >= 1 keeps per-
                                   # worker model estimates W_j, a
                                   # [n_workers] version vector and a
                                   # replay ring of the last R packed
                                   # s2w broadcast rounds, so a worker
                                   # absent <= R rounds catches up by
                                   # replaying compressed deltas and a
                                   # longer absence takes a full W
                                   # copy. Requires a compressing s2w
                                   # leg (the stream being replayed)


def _unzip(pairs: list, n: int) -> tuple[list, ...]:
    return tuple(list(x) for x in zip(*pairs)) if pairs else tuple([] for _ in range(n))


@dataclass(frozen=True)
class WireBudget:
    """The static per-step collective budget of the two wire legs — the
    single source of truth behind the byte-for-byte invariants: exactly
    ``len(w2s_sizes)`` w2s payload all-gathers and ``len(s2w_sizes)``
    s2w broadcast all-gathers, each moving exactly its listed u8 bytes
    per device (one entry per stage sub-buffer; monolithic => one
    entry; an unpacked direction => no entries). Consumed by the
    dry-run attribution, the SPMD wire tests and the §12 lint rules, so
    the compiled-program checks can never drift from the resolution the
    step function actually uses (``EF21Muon.wire_budget``)."""
    pack_w2s: bool
    pack_s2w: bool
    n_stages: int                  # effective pipeline stages (1 = mono)
    w2s_sizes: tuple[int, ...]     # expected u8 bytes, one per gather
    s2w_sizes: tuple[int, ...]
    # replica-group size of a direction gather (the worker axis): lets
    # the lint attribution tell wire gathers from the model-axis TP
    # repack the partitioner may lower as sub-group gathers/permutes
    n_workers: int = 1

    @property
    def w2s_nbytes(self) -> int:
        return sum(self.w2s_sizes)

    @property
    def s2w_nbytes(self) -> int:
        return sum(self.s2w_sizes)

    @property
    def two_way_nbytes(self) -> int:
        return self.w2s_nbytes + self.s2w_nbytes


def resolve_pack_s2w(cfg: EF21MuonConfig, distributed: bool) -> bool:
    """The resolved s2w pack switch (§9): requires a compressing C_P and
    a communication hook, then ``wire_pack_s2w`` with "auto" following
    ``wire_pack``. Shared by ``make_step`` and every byte account."""
    return (cfg.s2w != "identity" and distributed
            and (cfg.wire_pack if cfg.wire_pack_s2w == "auto"
                 else bool(cfg.wire_pack_s2w)))


def resolve_stage_plan(cfg: EF21MuonConfig, plan, mesh=None,
                       fsdp: bool = False, any_pack: bool = True):
    """The resolved stage partition (§8), or None when the pipeline
    collapses to the monolithic single-gather path: staging needs a
    packed direction, NS bucketing, ``wire_stages != 1`` and more than
    one effective stage."""
    if not (any_pack and cfg.ns_bucketing and cfg.wire_stages != 1):
        return None
    sp = plan.stage_plan(mesh=mesh, fsdp=fsdp, wire_stages=cfg.wire_stages,
                         ns_steps=cfg.ns_steps)
    return sp if sp.n_stages > 1 else None


class EF21Muon:
    def __init__(self, cfg: EF21MuonConfig):
        self.cfg = cfg
        self._plans: OrderedDict = OrderedDict()

    # ------------------------------------------------------------------ plan
    def plan(self, params: Any, metas: Any) -> LayerPlan:
        """The LayerPlan for this (treedef, shapes, dtypes, metas) —
        cached LRU (bounded at 8 entries, oldest dropped first), so init,
        every traced step and the wire accounting share one plan, and
        shape sweeps don't rebuild every live plan on eviction. Leaf
        dtypes are part of the key: switching param dtype must not reuse
        a stale plan (and its memoised wire layouts/buckets)."""
        leaves, treedef = jax.tree.flatten(params)
        metas_l = tuple(treedef.flatten_up_to(metas))
        key = (treedef, tuple(tuple(p.shape) for p in leaves),
               tuple(jnp.dtype(p.dtype).name for p in leaves), metas_l)
        if key in self._plans:
            self._plans.move_to_end(key)
        else:
            if len(self._plans) >= 8:
                self._plans.popitem(last=False)
            self._plans[key] = LayerPlan.build(
                params, metas, w2s=self.cfg.w2s, s2w=self.cfg.s2w)
        return self._plans[key]

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, params: Any, metas: Any) -> dict:
        cfg = self.cfg
        sd = cfg.state_dtype
        plan = self.plan(params, metas)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, sd), params)
        g_w = jax.tree.map(
            lambda p: jnp.zeros((cfg.n_workers,) + p.shape, sd), params)
        m_w = None if cfg.beta >= 1.0 else jax.tree.map(
            lambda p: jnp.zeros((cfg.n_workers,) + p.shape, sd), params)

        n = len(plan.leaves)
        keys = jax.random.split(key, n * (cfg.n_workers + 1))
        cw_states, cs_states = [], []
        for i, lp in enumerate(plan.leaves):
            wire = jnp.dtype(cfg.wire_dtype)
            wkeys = jax.random.split(
                keys[i], cfg.n_workers * lp.n_stack).reshape(
                    (cfg.n_workers,) + lp.stack_shape)
            cw_states.append(vmap_n(
                lambda k, c=lp.w2s, s=lp.slice_shape: c.init(k, s, wire),
                lp.meta.stack_dims + 1)(wkeys))
            skeys = jax.random.split(keys[n + i], lp.n_stack).reshape(
                lp.stack_shape) if lp.stack_shape else keys[n + i]
            cs_states.append(vmap_n(
                lambda k, c=lp.s2w, s=lp.slice_shape: c.init(k, s, wire),
                lp.meta.stack_dims)(skeys))

        state = {
            "step": jnp.zeros((), jnp.int32),
            "x": params,
            "g_server": zeros,
            "g_w": g_w,
            "m_w": m_w,
            "cw_state": plan.unflatten(cw_states),
        }
        if cfg.s2w != "identity":
            state["w"] = jax.tree.map(lambda p: p.astype(sd), params)
            state["cs_state"] = plan.unflatten(cs_states)
        ring_depth = resolve_ring_depth(cfg.resync)
        if ring_depth:
            if cfg.s2w == "identity":
                raise ValueError(
                    "resync requires a compressing s2w leg (s2w != "
                    "'identity'): rejoin replays the server->worker "
                    "broadcast stream")
            # per-worker model estimates W_j (§13): every worker starts
            # current, bit-equal to the server's W
            state["w_w"] = jax.tree.map(
                lambda p: jnp.broadcast_to(
                    p.astype(sd)[None], (cfg.n_workers,) + p.shape) + 0,
                params)
            state["resync"] = init_resync_state(
                cfg.n_workers, ring_depth,
                plan.wire_layout(cfg.wire_dtype,
                                 direction="s2w").total_nbytes)
        return state

    # ------------------------------------------------------------ bookkeeping
    def w2s_bytes_per_worker(self, params: Any, metas: Any) -> int:
        """Static wire cost of one worker->server message (Table 2)."""
        return self.plan(params, metas).w2s_bytes_per_worker(
            self.cfg.wire_dtype)

    def wire_bytes_per_worker(self, params: Any, metas: Any) -> int:
        """Exact bytes of the fused uint8 wire buffer (repro.wire) — what
        the payload all-gather actually moves, next to the analytic
        Table-2 number above."""
        return self.plan(params, metas).wire_layout(
            self.cfg.wire_dtype).total_nbytes

    def s2w_bytes_per_round(self, params: Any, metas: Any) -> int:
        """Static wire cost of one server->worker model-update broadcast
        (Table-2 convention, EF21-P direction)."""
        return self.plan(params, metas).s2w_bytes_per_round(
            self.cfg.wire_dtype)

    def wire_bytes_s2w(self, params: Any, metas: Any) -> int:
        """Exact bytes of the fused s2w uint8 broadcast buffer (§9) —
        what the model-update broadcast actually moves per round."""
        return self.plan(params, metas).wire_layout(
            self.cfg.wire_dtype, direction="s2w").total_nbytes

    def dense_bytes(self, params: Any) -> int:
        return dense_payload_bytes(
            (p.shape for p in jax.tree.leaves(params)), self.cfg.wire_dtype)

    def wire_budget(self, params: Any, metas: Any, mesh=None,
                    fsdp: bool = False,
                    distributed: bool = True) -> WireBudget:
        """The resolved :class:`WireBudget` for this config on
        ``params`` — the exact u8 collective population ``make_step``'s
        lowering emits, computed through the same ``resolve_pack_s2w``
        / ``resolve_stage_plan`` switches the step function uses.
        ``distributed=False`` models the hook-less single-process step
        (no collectives, both directions unpacked)."""
        cfg = self.cfg
        plan = self.plan(params, metas)
        pack_w2s = bool(cfg.wire_pack and distributed)
        pack_s2w = resolve_pack_s2w(cfg, distributed)
        splan = resolve_stage_plan(cfg, plan, mesh=mesh, fsdp=fsdp,
                                   any_pack=pack_w2s or pack_s2w)

        def sizes(direction: str, packed: bool) -> tuple[int, ...]:
            if not packed:
                return ()
            if splan is not None:
                sw = plan.staged_wire_layout(cfg.wire_dtype, splan,
                                             direction=direction)
                return tuple(sw.stage_nbytes(k)
                             for k in range(sw.n_stages))
            return (plan.wire_layout(
                cfg.wire_dtype, direction=direction).total_nbytes,)

        return WireBudget(pack_w2s, pack_s2w,
                          splan.n_stages if splan is not None else 1,
                          sizes("w2s", pack_w2s), sizes("s2w", pack_s2w),
                          n_workers=cfg.n_workers)

    # The jit-friendly entry point: metas are static, so we build the step
    # function once per (metas, shapes) and let the caller jit it.
    def make_step(self, metas: Any,
                  reshard_payloads: Callable | None = None,
                  mesh=None,
                  fsdp: bool = False,
                  reshard_updates: Callable | None = None,
                  faults=None) -> Callable:
        """``reshard_payloads`` is the cross-worker communication hook
        (the trainer's worker-axis all-gather). None means single-process
        — there is no collective to fuse, so the wire pack/unpack is
        skipped entirely (it is a values-identity either way).

        ``reshard_updates`` is the same hook for the opposite direction
        (§9): the tiled ``[n_workers, nbytes]`` s2w broadcast buffer —
        every worker-domain's copy of the server's compressed model
        update — is pinned to the worker axis and re-replicated, which
        lowers to one u8 all-gather per stage sub-buffer whose
        per-device operand bytes are exactly the s2w layout account
        (the per-link cost of a broadcast). Defaults to
        ``reshard_payloads``; pass one explicitly to split the hooks.

        ``mesh``/``fsdp`` make the bucketed phase-5 dispatch
        sharding-aware: each NS bucket carries its ``ns_bucket_pspec``
        and the batched chain is pinned to it (constraints on the jnp
        path, ``shard_map`` around the fused kernel on the Pallas path)
        instead of losing the per-leaf TP/zero-1 shardings at the bucket
        concat. Single-process callers leave them unset.

        ``faults`` is an optional ``train.faults.FaultPlan`` — a seeded,
        declared schedule of worker drops, poisoned gradient leaves and
        bit-flipped wire payloads injected inside the step (§11). Passing
        one forces the non-finite guard on."""
        cfg = self.cfg
        validate_spec(cfg.participation, cfg.n_workers)
        # elastic participation (§11): the masked fold/commit path is
        # built only when something can actually mask — participation
        # "full" without the guard takes the exact pre-§11 code path
        # (lowering-identical, the bit-equal A/B arm)
        guard = cfg.nonfinite_guard or faults is not None
        elastic = cfg.participation != "full" or guard
        ring_depth = resolve_ring_depth(cfg.resync)
        if ring_depth and cfg.s2w == "identity":
            raise ValueError(
                "resync requires a compressing s2w leg (s2w != "
                "'identity'): rejoin replays the server->worker "
                "broadcast stream")
        resync_on = ring_depth > 0
        pack_wire = cfg.wire_pack and reshard_payloads is not None
        if reshard_updates is None:
            reshard_updates = reshard_payloads
        pack_s2w = resolve_pack_s2w(cfg, reshard_updates is not None)
        if reshard_payloads is None:
            reshard_payloads = lambda tree: tree
        if reshard_updates is None:
            reshard_updates = lambda tree: tree

        def step(state: dict, grad_and_loss: Callable, batch: Any,
                 t: jax.Array | float) -> tuple[dict, dict]:
            plan = self.plan(state["x"], metas)

            # Observability (§10): in-graph MetricSet + phase/wire spans.
            # Both default off; the off arm takes the identical code path
            # (phase_span without graph= is a host TraceAnnotation only,
            # never a lowering change) so it compiles byte-identical to a
            # build without the obs layer. Metric reads never feed the
            # update, so the metrics-on arm stays value-bit-equal.
            gspan = cfg.trace_spans
            mset = MetricSet() if cfg.metrics else None
            lnames = leaf_names(state["x"]) if cfg.metrics else None

            # Stage structure first — both wire directions cut their
            # buffers along the same leaf partition (§8, §9).
            buckets = (plan.ns_buckets(mesh=mesh, fsdp=fsdp)
                       if cfg.ns_bucketing else ())
            bucketed = {i for b in buckets for i in b.leaf_ids}
            splan = resolve_stage_plan(cfg, plan, mesh=mesh, fsdp=fsdp,
                                       any_pack=pack_wire or pack_s2w)

            # ---- §13 reception mask: who hears THIS round's s2w
            # broadcast. Network-level semantics: scheduled absence and
            # declared drop faults gate reception; guard demotion does
            # NOT (a demoted worker's compute is poisoned, not its
            # downlink), so it is computed up front, before the guard
            # can see any payload.
            recv = None
            if resync_on:
                recv = reception_mask(
                    cfg.participation, cfg.n_workers, state["step"],
                    cfg.participation_seed, faults=faults)

            # ---- 1. EF21-P: workers' model estimate W (S = C_P(X - W)).
            # With s2w wire packing the broadcast leg is explicit (§9):
            # the server packs S into the s2w uint8 wire buffer, tiles
            # it to [n_workers, nbytes] (each row one worker-domain's
            # copy of the same message) and the reshard_updates hook
            # pins it to the worker axis then re-replicates — one u8
            # all-gather per stage sub-buffer whose per-device operand
            # is exactly the s2w layout bytes, i.e. the per-link cost
            # of the broadcast. W is then reconstructed from the *wire
            # bytes* via apply_payload, so server and workers advance
            # bit-identical EF21-P state; the unpacked arm
            # (wire_pack_s2w=False) is value-bit-equal because
            # pack -> unpack is bit-exact and apply_payload is the
            # same estimate update ef_compress_step performs.
            ring_row = None   # §13: this round's packed s2w bytes
            with phase_span(PHASE_SPANS[0], gspan):
                if cfg.s2w != "identity" and pack_s2w:
                    cs_f = plan.flatten(state["cs_state"])
                    w_f = plan.flatten(state["w"])
                    x_f0 = plan.flatten(state["x"])
                    s_payloads, cs_l = _unzip(plan.map_flat(
                        lambda lp, cs, w, x: ef_compress_step(
                            lp.s2w, cs, w, x, cfg.wire_dtype)[:2],
                        cs_f, w_f, x_f0), 2)
                    # lead dim 1: the server's single broadcast message
                    lead = [jax.tree.map(lambda a: a[None], p)
                            for p in s_payloads]

                    def broadcast(buf):
                        # The max-fold over the gathered (bit-identical
                        # u8) rows is a value identity that consumes
                        # EVERY row, so the partitioner cannot shrink or
                        # elide the gather behind the invariant.
                        tiled = jnp.broadcast_to(
                            buf, (cfg.n_workers,) + tuple(buf.shape[1:]))
                        return jnp.max(reshard_updates(tiled),
                                       axis=0, keepdims=True)

                    def s2w_apply(i, pl):
                        lp = plan.leaves[i]
                        return vmap_n(
                            lambda q, w: apply_payload(lp.s2w, q, w),
                            lp.meta.stack_dims)(
                                jax.tree.map(lambda a: a[0], pl), w_f[i])

                    w_l: list = [None] * len(plan.leaves)
                    if splan is not None:
                        swire = plan.staged_wire_layout(
                            cfg.wire_dtype, splan, direction="s2w")
                        order = s2w_issue_order(plan, splan)
                        # all K broadcasts issued up front, heaviest
                        # receive chain first (§9 overlap story)
                        sbufs = {}
                        for k in order:
                            with phase_span(wire_stage_span("s2w", k),
                                            gspan):
                                sbufs[k] = broadcast(
                                    swire.pack_stage(k, lead))
                        if resync_on:
                            # §13 replay ring row: this round's gathered
                            # broadcast bytes verbatim, stage sub-
                            # buffers concatenated in stage order
                            ring_row = jnp.concatenate(
                                [sbufs[k][0]
                                 for k in range(splan.n_stages)])
                        for k in order:
                            for i, pl in zip(
                                    splan.stages[k].leaf_ids,
                                    swire.unpack_stage(k, sbufs[k])):
                                w_l[i] = s2w_apply(i, pl)
                    else:
                        swire = plan.wire_layout(cfg.wire_dtype,
                                                 direction="s2w")
                        with phase_span(wire_stage_span("s2w", 0), gspan):
                            buf = broadcast(swire.pack(lead))
                        if resync_on:
                            ring_row = buf[0]
                        for i, pl in enumerate(swire.unpack(buf)):
                            w_l[i] = s2w_apply(i, pl)
                    w_tree = plan.unflatten(w_l)
                    cs_tree = plan.unflatten(cs_l)
                elif cfg.s2w != "identity":
                    s_payloads, cs_l, w_l = _unzip(plan.map_flat(
                        lambda lp, cs, w, x: ef_compress_step(
                            lp.s2w, cs, w, x, cfg.wire_dtype),
                        plan.flatten(state["cs_state"]),
                        plan.flatten(state["w"]),
                        plan.flatten(state["x"])), 3)
                    w_tree = plan.unflatten(w_l)
                    cs_tree = plan.unflatten(cs_l)
                    if resync_on:
                        # unpacked arm: no wire bytes exist, so pack the
                        # ring row locally through the same monolithic
                        # s2w layout — a value identity with the packed
                        # arm's gathered bytes (pack is deterministic
                        # and unpack is its bit-exact inverse)
                        lead = [jax.tree.map(lambda a: a[None], p)
                                for p in s_payloads]
                        ring_row = plan.wire_layout(
                            cfg.wire_dtype, direction="s2w").pack(lead)[0]
                else:
                    w_tree, cs_tree = state["x"], None

            # ---- §13 rejoin: push this round into the replay ring,
            # advance the version vector, and bring every receiving
            # worker's W_j current — by replaying missed rounds from the
            # ring (lag <= R, ascending round order, the exact
            # apply_payload algebra per slot) or by a full copy of the
            # server's post-round W (lag > R). Each ring slot is
            # decompressed ONCE (the broadcast was a single message) and
            # the per-worker application is where-masked, so replay adds
            # no collectives — the §8/§9 wire invariants are untouched.
            if resync_on:
                with phase_span("resync/replay", gspan):
                    ring_new = ring_push(state["resync"]["ring"],
                                         ring_row)
                    rm = replay_masks(state["resync"]["vv"],
                                      state["step"], recv, ring_depth)
                    if pack_s2w and splan is not None:
                        rswire = plan.staged_wire_layout(
                            cfg.wire_dtype, splan, direction="s2w")
                        offs = [0]
                        for k in range(rswire.n_stages):
                            offs.append(offs[-1] + rswire.stage_nbytes(k))

                        def unpack_row(row):
                            pls: list = [None] * len(plan.leaves)
                            for k in range(rswire.n_stages):
                                seg = jax.lax.slice_in_dim(
                                    row, offs[k], offs[k + 1])
                                for i, pl in zip(
                                        splan.stages[k].leaf_ids,
                                        rswire.unpack_stage(
                                            k, seg[None])):
                                    pls[i] = pl
                            return pls
                    else:
                        rswire = plan.wire_layout(cfg.wire_dtype,
                                                  direction="s2w")

                        def unpack_row(row):
                            return rswire.unpack(row[None])

                    slot_pls = [unpack_row(ring_new[r])
                                for r in range(ring_depth)]
                    w_srv_f = plan.flatten(w_tree)

                    def rejoin_leaf(i, w):
                        lp = plan.leaves[i]
                        for r in range(ring_depth):
                            delta = vmap_n(
                                lambda q, c=lp.s2w, s=lp.slice_shape:
                                c.decompress(q, s, jnp.float32),
                                lp.meta.stack_dims)(
                                    jax.tree.map(lambda a: a[0],
                                                 slot_pls[r][i]))
                            w = jnp.where(
                                mask_bcast(rm.apply[r], w.ndim),
                                (w.astype(jnp.float32)
                                 + delta[None]).astype(w.dtype), w)
                        return jnp.where(
                            mask_bcast(rm.full, w.ndim),
                            w_srv_f[i].astype(w.dtype)[None], w)

                    w_w_tree = plan.unflatten(
                        [rejoin_leaf(i, w) for i, w in
                         enumerate(plan.flatten(state["w_w"]))])

            # ---- 2. per-worker stochastic gradients at W (no cross-worker comm)
            with phase_span(PHASE_SPANS[1], gspan):
                if resync_on:
                    # §13: each worker differentiates at its OWN model
                    # estimate W_j (stale for desynchronized workers —
                    # their commits are frozen by the §11 mask anyway)
                    w_cast = jax.tree.map(
                        lambda w, x: w.astype(x.dtype), w_w_tree,
                        state["x"])
                    losses, grads = jax.vmap(
                        grad_and_loss, in_axes=(0, 0))(w_cast, batch)
                else:
                    w_cast = jax.tree.map(
                        lambda w, x: w.astype(x.dtype), w_tree,
                        state["x"])
                    losses, grads = jax.vmap(
                        grad_and_loss, in_axes=(None, 0))(w_cast, batch)
                if faults is not None:
                    # poisoned gradient leaves (§11): NaN/Inf injected on
                    # the declared schedule — flows through momentum into
                    # the payload, where the non-finite guard demotes the
                    # worker. Losses stay clean: the injection models a
                    # corrupted backward pass, not a diverged model.
                    grads = faults.inject_grads(grads, state["step"])

            # ---- 3. momentum + EF21 per worker: R_j = C_D(M_j - G_j)
            with phase_span(PHASE_SPANS[2], gspan):
                beta = cfg.beta
                if state["m_w"] is not None:
                    m_new = jax.tree.map(
                        lambda m, g: ((1.0 - beta) * m.astype(jnp.float32)
                                      + beta * g.astype(jnp.float32)
                                      ).astype(m.dtype),
                        state["m_w"], grads)
                else:
                    m_new = jax.tree.map(
                        lambda g: g.astype(cfg.state_dtype), grads)

                gw_old = plan.flatten(state["g_w"])
                payloads, cw_l, gw_l = _unzip(plan.map_flat(
                    lambda lp, cw, gw, m: ef_compress_step(
                        lp.w2s, cw, gw, m, cfg.wire_dtype),
                    plan.flatten(state["cw_state"]),
                    gw_old,
                    plan.flatten(m_new), extra_vmap=1), 3)

            # ---- 4.+5. server receive + layer-wise LMO. Shared per-leaf
            # pieces first: decompress one leaf's gathered payloads, pin
            # the decompressed deltas replicated (§5: the payload buffer
            # was just all-gathered to every device — without the pin the
            # phase-5 bucket constraints propagate backward through
            # decompress and the partitioner reshards the *compressed u8
            # payloads*, splitting the fused payload all-gathers the wire
            # invariant in tests/test_sharding.py pins), fold the worker
            # mean into g_server, and the per-leaf / per-bucket LMOs.
            rep = None
            if cfg.ns_bucketing and isinstance(mesh, jax.sharding.Mesh):
                rep = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())

            # ---- elastic participation (§11): the scheduled mask comes
            # from the step counter; the guard ANDs in per-worker payload
            # finiteness AFTER unpack (so torn wire buffers are caught
            # too). resolve_mask returns the final mask, the dynamic-
            # count fold denominator, the skip-step flag (no survivors)
            # and the demoted-by-guard count.
            sched_mask = recv   # §13 arm: same conjunction, computed
            if elastic and sched_mask is None:  # up front for the ring
                sched_mask = participation_mask(
                    cfg.participation, cfg.n_workers, state["step"],
                    cfg.participation_seed)
                if faults is not None:
                    sched_mask = sched_mask & faults.drop_mask(
                        state["step"])

            def resolve_mask(recv_payloads):
                m = sched_mask
                demoted = jnp.zeros((), jnp.int32)
                if guard:
                    finite = payload_finite_mask(recv_payloads,
                                                 cfg.n_workers)
                    demoted = jnp.sum((m & ~finite).astype(jnp.int32))
                    m = m & finite
                cnt = jnp.sum(m.astype(jnp.int32))
                return (m, jnp.maximum(cnt, 1).astype(jnp.float32),
                        cnt > 0, demoted)

            def recv_leaf(i, pl, gs, part=None):
                lp = plan.leaves[i]
                d = vmap_n(lambda s: lp.w2s.decompress(
                    s, lp.slice_shape, jnp.float32),
                    lp.meta.stack_dims + 1)(pl)
                if rep is not None:
                    d = jax.lax.with_sharding_constraint(d, rep)
                if part is not None:
                    # mask-weighted fold over the dynamic participant
                    # count; where (not multiply) so a demoted worker's
                    # NaNs never reach the sum
                    m, denom = part[0], part[1]
                    d = jnp.where(mask_bcast(m, d.ndim), d, 0.0)
                    return (gs.astype(jnp.float32)
                            + jnp.sum(d, axis=0) / denom).astype(gs.dtype)
                return (gs.astype(jnp.float32)
                        + jnp.mean(d, axis=0)).astype(gs.dtype)

            def lmo_leaf(lp, x, g):
                d = lmo_direction(g, lp.meta.lmo, ns_steps=cfg.ns_steps,
                                  use_pallas=cfg.use_pallas)
                radius = jnp.asarray(t, jnp.float32) * lp.meta.radius_scale
                return (x.astype(jnp.float32)
                        + radius * d.astype(jnp.float32)).astype(x.dtype)

            def lmo_bucket(bi, b, gs_l, x_flat, x_l):
                g_b = b.stack([gs_l[i] for i in b.leaf_ids], mesh=mesh)
                d_b = lmo_direction_batched(
                    g_b, ns_steps=cfg.ns_steps,
                    use_pallas=cfg.use_pallas, mesh=mesh, pspec=b.pspec)
                if mset is not None:
                    # NS orthogonality residual per bucket (§10): how far
                    # the batched chain's output is from U Vᵀ — a pure
                    # read of d_b, never fed back into the update
                    m_, n_ = b.shape
                    mset.add(f"ns/orth_residual/b{bi}_{m_}x{n_}",
                             orth_residual(d_b))
                x_b = b.stack([x_flat[i] for i in b.leaf_ids],
                              dtype=jnp.float32, mesh=mesh)
                x_b = x_b + (b.radius_vector(t)[:, None, None]
                             * d_b.astype(jnp.float32))
                for i, piece in zip(b.leaf_ids, b.unstack(x_b, mesh=mesh)):
                    x_l[i] = piece.astype(x_flat[i].dtype)

            gsrv_l = plan.flatten(state["g_server"])
            x_flat = plan.flatten(state["x"])
            if pack_wire and splan is not None:
                # ---- staged wire pipeline (DESIGN.md §8): the §6 buffer
                # repartitioned into K stage sub-buffers aligned with the
                # NS buckets that consume them. All K gathers are issued
                # up front — K independent all-gather start/done pairs
                # for the latency-hiding scheduler — then each stage's
                # unpack -> decompress -> g_server fold -> batched LMO
                # consumes only its own sub-buffer, so the long NS chains
                # of the early (biggest-FLOP) stages overlap the still-
                # in-flight gathers of the later ones. Value-bit-equal to
                # the monolithic path: staging is a pure repartition.
                swire = plan.staged_wire_layout(cfg.wire_dtype, splan)
                bufs = []
                with phase_span(PHASE_SPANS[3], gspan):
                    for k in range(splan.n_stages):
                        with phase_span(wire_stage_span("w2s", k), gspan):
                            buf = reshard_payloads(
                                swire.pack_stage(k, payloads))
                            if faults is not None:
                                buf = faults.inject_wire(
                                    buf, state["step"], k, "w2s")
                            bufs.append(buf)
                gs_l: list = [None] * len(plan.leaves)
                x_l: list = [None] * len(plan.leaves)
                part = None
                staged_pl: list = [None] * len(plan.leaves)
                if elastic:
                    # the guard's per-worker demotion is a STEP-global
                    # decision, so every stage unpacks before the first
                    # fold (§11 degradation semantics: the K gathers
                    # still issue up front and keep their §8 bytes/
                    # counts, but the folds now wait on all of them —
                    # robustness trades away some overlap)
                    with phase_span(PHASE_SPANS[3], gspan):
                        for k, stage in enumerate(splan.stages):
                            for i, pl in zip(
                                    stage.leaf_ids,
                                    swire.unpack_stage(k, bufs[k])):
                                staged_pl[i] = pl
                        part = resolve_mask(staged_pl)
                for k, stage in enumerate(splan.stages):
                    with phase_span(PHASE_SPANS[3], gspan):
                        pls = ([staged_pl[i] for i in stage.leaf_ids]
                               if elastic
                               else swire.unpack_stage(k, bufs[k]))
                        for i, pl in zip(stage.leaf_ids, pls):
                            gs_l[i] = recv_leaf(i, pl, gsrv_l[i], part)
                    with phase_span(PHASE_SPANS[4], gspan):
                        for bi in stage.bucket_ids:
                            lmo_bucket(bi, buckets[bi], gs_l, x_flat, x_l)
                        for i in stage.leaf_ids:
                            if i not in bucketed:   # stage-0 eager leaves
                                lp = plan.leaves[i]
                                x_l[i] = vmap_n(partial(lmo_leaf, lp),
                                                lp.meta.stack_dims)(
                                                    x_flat[i], gs_l[i])
            else:
                # ---- monolithic phase 4: pack the whole message into
                # one contiguous uint8 buffer (repro.wire), gather it
                # across the worker axis (trainer hook == ONE fused
                # all-gather of exactly the accounted bytes), unpack
                # bit-exactly, decompress, average.
                with phase_span(PHASE_SPANS[3], gspan):
                    if pack_wire:
                        wire = plan.wire_layout(cfg.wire_dtype)
                        with phase_span(wire_stage_span("w2s", 0), gspan):
                            buf = reshard_payloads(wire.pack(payloads))
                            if faults is not None:
                                buf = faults.inject_wire(
                                    buf, state["step"], 0, "w2s")
                        payloads = wire.unpack(buf)
                    else:
                        payloads = reshard_payloads(payloads)
                    part = resolve_mask(payloads) if elastic else None
                    gs_l = [recv_leaf(i, pl, gs, part) for i, (pl, gs)
                            in enumerate(zip(payloads, gsrv_l))]

                # ---- monolithic phase 5: layer-wise LMO on the server
                # iterate. With ns_bucketing the spectral leaves run one
                # batched Newton-Schulz chain per shape bucket (§7),
                # stacks folded into the batch dim, radii as a [B]
                # vector — bit-equal to the per-leaf path on jnp.
                with phase_span(PHASE_SPANS[4], gspan):
                    if cfg.ns_bucketing:
                        x_l = [
                            x if i in bucketed else
                            vmap_n(partial(lmo_leaf, lp),
                                   lp.meta.stack_dims)(x, g)
                            for i, (lp, x, g) in enumerate(
                                zip(plan.leaves, x_flat, gs_l))]
                        for bi, b in enumerate(buckets):
                            lmo_bucket(bi, b, gs_l, x_flat, x_l)
                    else:
                        x_l = plan.map_flat(lmo_leaf, x_flat, gs_l)

            if elastic:
                # ---- §11 commit: absent/demoted workers' EF21 error
                # state (G_j), momentum and compressor sketches are
                # bitwise FROZEN (the Gluon-FL partial-participation
                # contraction argument needs exactly this); if no worker
                # survived — every payload poisoned — the whole step
                # falls back to a global skip: X and g_server do not
                # move (the fold already added exactly 0, but the LMO
                # direction of a stale g must not be walked either).
                effm, _, any_p, n_demoted = part

                def freeze(new, old):
                    return jax.tree.map(
                        lambda n, o: jnp.where(
                            mask_bcast(effm, n.ndim), n, o), new, old)

                gw_l = [freeze(n, o) for n, o in zip(gw_l, gw_old)]
                cw_l = [freeze(n, o) for n, o in
                        zip(cw_l, plan.flatten(state["cw_state"]))]
                if state["m_w"] is not None:
                    m_new = freeze(m_new, state["m_w"])
                x_l = [jnp.where(any_p, xn, xo)
                       for xn, xo in zip(x_l, x_flat)]
                gs_l = [jnp.where(any_p, gn, go)
                        for gn, go in zip(gs_l, gsrv_l)]
                if mset is not None:
                    mset.add("part/n_participants",
                             jnp.sum(effm.astype(jnp.float32)))
                    mset.add("part/demoted",
                             n_demoted.astype(jnp.float32))
                    mset.add("part/skipped_step",
                             1.0 - any_p.astype(jnp.float32))

            if mset is not None:
                # Per-leaf EF21 telemetry (§10) — pure reads of tensors
                # the phases above already hold. v = M_j - G_j is the
                # compressed target, C(v) = G_j' - G_j the decompressed
                # message, so ‖M_j - G_j'‖ is both the post-update EF21
                # error e_t and the compression residual ‖C(v) - v‖.
                m_flat = plan.flatten(m_new)
                wnew_f = (plan.flatten(w_tree)
                          if cfg.s2w != "identity" else None)
                for i, nm in enumerate(lnames):
                    err = (m_flat[i].astype(jnp.float32)
                           - gw_l[i].astype(jnp.float32))
                    v = (m_flat[i].astype(jnp.float32)
                         - gw_old[i].astype(jnp.float32))
                    mset.add(f"ef/err_norm/{nm}", worker_mean_norm(err))
                    mset.add(f"ef/rel_err/{nm}", rel_error(err, v))
                    mset.add(f"ef/momentum_norm/{nm}",
                             worker_mean_norm(m_flat[i]))
                    if wnew_f is not None:
                        # EF21-P model-estimate error ‖X - W‖ (s2w leg)
                        mset.add(f"efp/err_norm/{nm}", worker_mean_norm(
                            x_flat[i].astype(jnp.float32)
                            - wnew_f[i].astype(jnp.float32), lead=0))
                # static per-direction wire accounting (constants in the
                # graph — the sink's per-step rows stay self-describing)
                mset.add("wire/bytes_w2s", float(
                    plan.wire_layout(cfg.wire_dtype).total_nbytes))
                mset.add("wire/bytes_s2w", float(
                    plan.wire_layout(cfg.wire_dtype,
                                     direction="s2w").total_nbytes
                    if cfg.s2w != "identity" else 0.0))
                mset.add("wire/n_stages", float(
                    splan.n_stages if splan is not None else 1))
                if resync_on:
                    # §13 rejoin telemetry — pure reads of the replay
                    # mask algebra, never fed back into the update
                    mset.add("part/worker_version_lag_max",
                             rm.lag_max.astype(jnp.float32))
                    mset.add("resync/replayed",
                             rm.n_replayed.astype(jnp.float32))
                    mset.add("resync/full",
                             rm.n_full.astype(jnp.float32))

            new_state = {
                "step": state["step"] + 1,
                "x": plan.unflatten(x_l),
                "g_server": plan.unflatten(gs_l),
                "g_w": plan.unflatten(gw_l),
                "m_w": m_new if state["m_w"] is not None else None,
                "cw_state": plan.unflatten(cw_l),
            }
            if cfg.s2w != "identity":
                new_state["w"] = w_tree
                new_state["cs_state"] = cs_tree
            if resync_on:
                # §13: worker estimates, version vector and ring advance
                # even on a skipped step — the server's W advanced too,
                # and the broadcast stream must stay contiguous
                new_state["w_w"] = w_w_tree
                new_state["resync"] = {"vv": rm.vv_new, "ring": ring_new}
            aux = {"loss": jnp.mean(losses),
                   "grad_est_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in gs_l))}
            if elastic:
                aux["participation"] = part[0]
                aux["n_participants"] = jnp.sum(part[0].astype(jnp.int32))
                aux["skipped"] = ~part[2]
            if resync_on:
                aux["resync_replayed"] = rm.n_replayed
                aux["resync_full"] = rm.n_full
                aux["version_lag_max"] = rm.lag_max
            if mset is not None:
                aux["metrics"] = mset
            return new_state, aux

        return step
