# EF21-Muon core: LMO geometry + contractive compression + error feedback.
from .adamw import adamw_init, adamw_update
from .compressors import get_compressor
from .gluon import gluon_init, gluon_update
from .lmo import (default_radius_scale, lmo_direction, lmo_direction_batched,
                  lmo_step, sharp)
from .muon import EF21Muon, EF21MuonConfig, ParamMeta, meta_like
from .norms import dual_norm, norm

__all__ = [
    "EF21Muon", "EF21MuonConfig", "ParamMeta", "meta_like",
    "gluon_init", "gluon_update", "adamw_init", "adamw_update",
    "lmo_direction", "lmo_direction_batched", "lmo_step", "sharp",
    "default_radius_scale",
    "get_compressor", "norm", "dual_norm",
]
