"""Shape-bucketed Newton-Schulz dispatch (DESIGN.md §7).

A transformer has dozens of identically-shaped spectral matrices
(attention projections, MLP in/out, per layer), but phase 5 of the
optimizer used to lower one independent NS chain per leaf. This module
groups the spectral leaves of a ``LayerPlan`` into **shape buckets** so
the step runs ONE batched NS dispatch chain per distinct slice shape:

  * the bucket key is the *canonical* slice shape ``(m, n)`` with
    ``m <= n`` — a ``[768, 3072]`` up-projection and a ``[3072, 768]``
    down-projection land in the same bucket, with a per-leaf transpose
    flag recording the orientation fix applied during stacking;
  * stacked leaves (``stack_dims > 0``, e.g. ``[L, ...]`` layer stacks or
    ``[L, E, ...]`` expert stacks) fold their stack dims into the batch
    dimension natively — a single ``reshape`` instead of nested vmaps, so
    the whole stack rides one batched kernel grid;
  * per-bucket static metadata includes the per-slice LMO radius scales
    as a length-``batch`` vector, so the trust-region update is applied
    batched too;
  * when built against a mesh, each bucket carries the static
    ``ns_bucket_pspec`` for its ``[B, m, n]`` stack (batch dim over the
    largest divisible slow axis, trailing ``model`` dim when the member
    TP orientations agree) and ``stack``/``unstack`` pin it with
    ``with_sharding_constraint`` — without this the bucket concat drops
    the per-leaf TP/zero-1 shardings and the partitioner replicates the
    whole NS chain (the +13.7% per-device FLOP regression this fixes).

``stack``/``unstack`` are exact inverses (transpose + reshape only, no
arithmetic) and sharding constraints are value-identities, so the
bucketed step stays bit-equal to the per-leaf step on the jnp path —
asserted in tests/test_ns_bucketing.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.dist.sharding import ns_bucket_pspec, param_pspec


@dataclass(frozen=True)
class NSBucket:
    """Static description of one shape bucket of spectral leaves."""
    shape: tuple[int, int]             # canonical slice shape, m <= n
    leaf_ids: tuple[int, ...]          # indices into plan.leaves (treedef order)
    leaf_shapes: tuple[tuple[int, ...], ...]  # full leaf shapes (with stack)
    transposes: tuple[bool, ...]       # per leaf: slice stored as [n, m]
    counts: tuple[int, ...]            # per leaf: n_stack slices contributed
    radius_scales: tuple[float, ...]   # per slice, len == batch
    pspec: Any = None                  # PartitionSpec of the [B, m, n] stack
                                       # (ns_bucket_pspec; None off-mesh)

    @property
    def batch(self) -> int:
        return sum(self.counts)

    # ------------------------------------------------------------ sharding
    def _constrain(self, x: jax.Array, mesh) -> jax.Array:
        """Pin the stacked array to the bucket's PartitionSpec (needs a
        live mesh for the NamedSharding; a no-op when the bucket was
        built without one)."""
        if self.pspec is None or not isinstance(mesh, jax.sharding.Mesh):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, self.pspec))

    # ------------------------------------------------------------ stacking
    def stack(self, leaves: list[jax.Array], dtype=None,
              mesh=None) -> jax.Array:
        """Fold per-leaf arrays ``[*stack, s0, s1]`` into one canonical
        ``[batch, m, n]`` stack: reshape the stack dims into the batch dim,
        swap the trailing axes of transposed leaves, concatenate in
        ``leaf_ids`` order. Transpose + reshape only — value-exact. With a
        mesh, the result is pinned to the bucket's ``pspec``."""
        if dtype is None:
            if len({x.dtype for x in leaves}) > 1:
                offenders = ", ".join(
                    f"leaf {lid}[{sh}]: {x.dtype}" for lid, sh, x in
                    zip(self.leaf_ids, self.leaf_shapes, leaves))
                raise TypeError(
                    f"NSBucket.stack: mixed leaf dtypes in bucket "
                    f"{self.shape} ({offenders}) — pass dtype= to unify")
        parts = []
        for x, tr in zip(leaves, self.transposes):
            x = x.reshape((-1,) + x.shape[x.ndim - 2:])
            if tr:
                x = jnp.swapaxes(x, -1, -2)
            parts.append(x if dtype is None else x.astype(dtype))
        out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
        return self._constrain(out, mesh)

    def unstack(self, batch: jax.Array, mesh=None) -> list[jax.Array]:
        """Exact inverse of ``stack`` (up to dtype, which the caller
        restores): split the batch dim back into per-leaf slabs, undo the
        orientation swap, restore the stack dims. With a mesh, the
        incoming stack is pinned to the bucket's ``pspec`` first so the
        whole batched chain ends sharded."""
        batch = self._constrain(batch, mesh)
        out, off = [], 0
        for full_shape, tr, cnt in zip(self.leaf_shapes, self.transposes,
                                       self.counts):
            piece = jax.lax.slice_in_dim(batch, off, off + cnt, axis=0)
            off += cnt
            if tr:
                piece = jnp.swapaxes(piece, -1, -2)
            out.append(piece.reshape(full_shape))
        return out

    def radius_vector(self, t) -> jax.Array:
        """Per-slice trust-region radii ``t * scale_i`` as a [batch] f32
        vector (broadcast over the stacked update)."""
        scales = jnp.asarray(self.radius_scales, jnp.float32)
        return jnp.asarray(t, jnp.float32) * scales


def build_buckets(plan, mesh=None, fsdp: bool = False) -> tuple[NSBucket, ...]:
    """Group the spectral 2-D leaves of a LayerPlan by canonical slice
    shape. Deterministic: buckets sorted by shape (then TP orientation),
    leaves in treedef order within a bucket. Non-spectral leaves (and any
    spectral leaf without a 2-D slice, which the per-leaf LMO would
    reject anyway) are left to the per-leaf path.

    With ``mesh`` (shape-only stand-ins work — only ``mesh.shape`` /
    ``mesh.axis_names`` are read), each bucket additionally carries its
    static ``ns_bucket_pspec``, derived from the member leaves'
    ``param_pspec`` with the canonical transpose applied — and shape
    groups are **sub-split by canonical TP orientation**: a transposed
    up/down-projection pair puts its ``model`` axis on opposite canonical
    dims, and no single stack layout can TP-shard both, so one merged
    bucket would leave the (FLOP-dominant) pair replicated over the model
    axis. Splitting keeps every sub-bucket's orientation consistent, the
    trailing-dim rule fires, and each sub-stack runs model-sharded —
    at the cost of one extra dispatch chain per mixed shape, which the
    512-chip dry-run shows is FLOP-neutral noise next to the replication
    it removes."""
    model_n = mesh.shape.get("model", 1) if mesh is not None else 1
    groups: dict[tuple, list] = {}
    for i, lp in enumerate(plan.leaves):
        if lp.meta.lmo != "spectral" or len(lp.slice_shape) != 2:
            continue
        s0, s1 = lp.slice_shape
        tr = s0 > s1
        shape = (s1, s0) if tr else (s0, s1)
        spec = mpos = None
        smodel = False
        if mesh is not None and model_n > 1:
            full = tuple(param_pspec(lp.meta, lp.shape, mesh, fsdp=fsdp))
            row, col = full[-2], full[-1]
            if tr:
                row, col = col, row
            spec = (row, col)
            mpos = 0 if row == "model" else (1 if col == "model" else None)
            smodel = "model" in full[:-2]   # expert-parallel stack dim
        groups.setdefault((shape, mpos), []).append((i, lp, tr, spec, smodel))
    # fold no-TP members into the single TP-orientation group of their
    # shape (ns_bucket_pspec ignores them when judging orientation, and
    # one dispatch chain beats two) — unless they carry ``model`` on a
    # stack dim (expert parallelism): the expert dim folds into the
    # batch dim, where batch-axis model sharding beats trailing TP, so
    # those keep their own bucket.
    if model_n > 1:
        for shape in {s for s, _ in groups}:
            tp = [p for s, p in groups if s == shape and p is not None]
            none_members = groups.get((shape, None))
            if none_members and len(tp) == 1 \
                    and not any(sm for *_, sm in none_members):
                groups[(shape, tp[0])] = sorted(
                    groups[(shape, tp[0])] + groups.pop((shape, None)))
    buckets = []
    for key in sorted(groups, key=lambda k: (k[0], -1 if k[1] is None
                                             else k[1])):
        shape, _ = key
        members = groups[key]
        scales = []
        for _, lp, *_ in members:
            scales.extend([float(lp.meta.radius_scale)] * lp.n_stack)
        pspec = None
        if mesh is not None:
            pspec = ns_bucket_pspec(
                sum(lp.n_stack for _, lp, *_ in members), shape,
                [spec for *_, spec, _ in members if spec is not None],
                mesh, stack_model=any(sm for *_, sm in members))
            if all(a is None for a in pspec):
                pspec = None
        buckets.append(NSBucket(
            shape=shape,
            leaf_ids=tuple(i for i, *_ in members),
            leaf_shapes=tuple(lp.shape for _, lp, *_ in members),
            transposes=tuple(tr for _, _, tr, *_ in members),
            counts=tuple(lp.n_stack for _, lp, *_ in members),
            radius_scales=tuple(scales),
            pspec=pspec))
    return tuple(buckets)
