"""Shape-bucketed Newton-Schulz dispatch (DESIGN.md §7).

A transformer has dozens of identically-shaped spectral matrices
(attention projections, MLP in/out, per layer), but phase 5 of the
optimizer used to lower one independent NS chain per leaf. This module
groups the spectral leaves of a ``LayerPlan`` into **shape buckets** so
the step runs ONE batched NS dispatch chain per distinct slice shape:

  * the bucket key is the *canonical* slice shape ``(m, n)`` with
    ``m <= n`` — a ``[768, 3072]`` up-projection and a ``[3072, 768]``
    down-projection land in the same bucket, with a per-leaf transpose
    flag recording the orientation fix applied during stacking;
  * stacked leaves (``stack_dims > 0``, e.g. ``[L, ...]`` layer stacks or
    ``[L, E, ...]`` expert stacks) fold their stack dims into the batch
    dimension natively — a single ``reshape`` instead of nested vmaps, so
    the whole stack rides one batched kernel grid;
  * per-bucket static metadata includes the per-slice LMO radius scales
    as a length-``batch`` vector, so the trust-region update is applied
    batched too.

``stack``/``unstack`` are exact inverses (transpose + reshape only, no
arithmetic), so the bucketed step stays bit-equal to the per-leaf step on
the jnp path — asserted in tests/test_ns_bucketing.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class NSBucket:
    """Static description of one shape bucket of spectral leaves."""
    shape: tuple[int, int]             # canonical slice shape, m <= n
    leaf_ids: tuple[int, ...]          # indices into plan.leaves (treedef order)
    leaf_shapes: tuple[tuple[int, ...], ...]  # full leaf shapes (with stack)
    transposes: tuple[bool, ...]       # per leaf: slice stored as [n, m]
    counts: tuple[int, ...]            # per leaf: n_stack slices contributed
    radius_scales: tuple[float, ...]   # per slice, len == batch

    @property
    def batch(self) -> int:
        return sum(self.counts)

    # ------------------------------------------------------------ stacking
    def stack(self, leaves: list[jax.Array], dtype=None) -> jax.Array:
        """Fold per-leaf arrays ``[*stack, s0, s1]`` into one canonical
        ``[batch, m, n]`` stack: reshape the stack dims into the batch dim,
        swap the trailing axes of transposed leaves, concatenate in
        ``leaf_ids`` order. Transpose + reshape only — value-exact."""
        parts = []
        for x, tr in zip(leaves, self.transposes):
            x = x.reshape((-1,) + x.shape[x.ndim - 2:])
            if tr:
                x = jnp.swapaxes(x, -1, -2)
            parts.append(x if dtype is None else x.astype(dtype))
        if len({p.dtype for p in parts}) > 1:
            raise TypeError(
                f"NSBucket.stack: mixed leaf dtypes "
                f"{[str(p.dtype) for p in parts]} — pass dtype= to unify")
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

    def unstack(self, batch: jax.Array) -> list[jax.Array]:
        """Exact inverse of ``stack`` (up to dtype, which the caller
        restores): split the batch dim back into per-leaf slabs, undo the
        orientation swap, restore the stack dims."""
        out, off = [], 0
        for full_shape, tr, cnt in zip(self.leaf_shapes, self.transposes,
                                       self.counts):
            piece = jax.lax.slice_in_dim(batch, off, off + cnt, axis=0)
            off += cnt
            if tr:
                piece = jnp.swapaxes(piece, -1, -2)
            out.append(piece.reshape(full_shape))
        return out

    def radius_vector(self, t) -> jax.Array:
        """Per-slice trust-region radii ``t * scale_i`` as a [batch] f32
        vector (broadcast over the stacked update)."""
        scales = jnp.asarray(self.radius_scales, jnp.float32)
        return jnp.asarray(t, jnp.float32) * scales


def build_buckets(plan) -> tuple[NSBucket, ...]:
    """Group the spectral 2-D leaves of a LayerPlan by canonical slice
    shape. Deterministic: buckets sorted by shape, leaves in treedef
    order within a bucket. Non-spectral leaves (and any spectral leaf
    without a 2-D slice, which the per-leaf LMO would reject anyway) are
    left to the per-leaf path."""
    groups: dict[tuple[int, int], list] = {}
    for i, lp in enumerate(plan.leaves):
        if lp.meta.lmo != "spectral" or len(lp.slice_shape) != 2:
            continue
        s0, s1 = lp.slice_shape
        tr = s0 > s1
        key = (s1, s0) if tr else (s0, s1)
        groups.setdefault(key, []).append((i, lp, tr))
    buckets = []
    for key in sorted(groups):
        members = groups[key]
        scales = []
        for _, lp, _ in members:
            scales.extend([float(lp.meta.radius_scale)] * lp.n_stack)
        buckets.append(NSBucket(
            shape=key,
            leaf_ids=tuple(i for i, _, _ in members),
            leaf_shapes=tuple(lp.shape for _, lp, _ in members),
            transposes=tuple(tr for _, _, tr in members),
            counts=tuple(lp.n_stack for _, lp, _ in members),
            radius_scales=tuple(scales)))
    return tuple(buckets)
