"""Mesh partition rules for EF21-Muon training and serving (DESIGN.md §3).

One home for every placement decision in the repo: the trainer, the
serving engine and the multi-pod dry-run all derive their shardings from
these functions instead of hand-rolling per-leaf PartitionSpecs.

Worker <-> mesh mapping (DESIGN.md §3): EF21 workers are the slow-link
domains of the mesh — pods on a multi-pod mesh, the data-parallel groups
on a single pod. ``worker_axis_for`` names that axis; arrays with a
leading worker dimension (per-worker gradients ``g_w``, momentum ``m_w``,
train batches, w2s payloads) are sharded over it, so the payload
all-gather in the lowered HLO crosses exactly the slow links and nothing
else.

Parameter rule (``param_pspec``):
  * tensor parallelism shards the *last* core dim divisible by the
    ``model`` axis (falling back to earlier dims);
  * stacks with ``stack_dims >= 2`` (routed experts ``[L, E, ...]``) are
    expert-parallel: the expert dim goes on ``model`` when divisible;
  * FSDP additionally shards one remaining divisible dim over ``data``;
  * vectors (core rank < 2) are replicated — they are tiny.

All spec builders only read ``mesh.shape`` / ``mesh.axis_names`` so they
work with shape-only mesh stand-ins (tests) and real meshes alike; only
``to_shardings`` needs a live ``jax.sharding.Mesh``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def worker_axis_for(mesh) -> str:
    """Mesh axis that carries the EF21 worker dimension: ``pod`` on a
    multi-pod mesh, else ``data`` (DESIGN.md §3)."""
    return "pod" if "pod" in mesh.axis_names else "data"


def n_workers_for(mesh) -> int:
    """EF21 workers = slow-link domains: pods on a multi-pod mesh, the
    data-parallel groups on a single pod (DESIGN.md §3)."""
    return mesh.shape[worker_axis_for(mesh)]


def param_pspec(meta, shape: tuple[int, ...], mesh, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    ``meta`` is ParamMeta-like (reads ``stack_dims`` only). See the module
    docstring for the rule; the leading ``stack_dims`` dims are the
    layer/expert stack, the rest is the core operand.
    """
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1)
    nd = len(shape)
    sd = min(meta.stack_dims, nd)
    axes: list[str | None] = [None] * nd
    core = shape[sd:]
    if len(core) < 2:
        return P(*axes)  # vectors (and scalars) replicated

    if model_n > 1:
        if sd >= 2 and shape[sd - 1] % model_n == 0:
            axes[sd - 1] = "model"        # expert parallelism on [L, E, ...]
        else:
            for i in range(nd - 1, sd - 1, -1):
                if shape[i] % model_n == 0:
                    axes[i] = "model"     # TP on last divisible core dim
                    break
    if fsdp and data_n > 1:
        for i in range(nd - 1, -1, -1):
            if axes[i] is None and shape[i] % data_n == 0:
                axes[i] = "data"
                break
    return P(*axes)


def param_pspecs(params: Any, metas: Any, mesh, fsdp: bool = False) -> Any:
    """``param_pspec`` over a whole params tree (``metas`` mirrors it);
    the one place the tree assembly lives — serving and the dry-run both
    call here."""
    treedef = jax.tree.structure(params)
    metas_l = treedef.flatten_up_to(metas)
    return treedef.unflatten(
        [param_pspec(m, p.shape, mesh, fsdp=fsdp)
         for p, m in zip(treedef.flatten_up_to(params), metas_l)])


def _worker_pspec(meta, shape: tuple[int, ...], mesh, fsdp: bool) -> P:
    """Spec for a leaf with a leading worker dim ((n_workers,) + param)."""
    waxis = worker_axis_for(mesh)
    inner = list(param_pspec(meta, shape[1:], mesh, fsdp=fsdp))
    lead = waxis if mesh.shape.get(waxis, 1) > 1 \
        and shape[0] % mesh.shape[waxis] == 0 else None
    if lead is not None and lead in inner:
        # an axis can appear once per spec: the worker dim wins, the
        # FSDP/TP use of the same axis on this leaf is dropped
        inner[inner.index(lead)] = None
    return P(lead, *inner)


def _zero1_pspec(meta, shape: tuple[int, ...], mesh, fsdp: bool) -> P:
    """Beyond-paper ZeRO-1-style layer-parallel LMO rule: shard the
    leading layer-stack dim of the *server* state (``x``, ``g_server``,
    ``w``) over ``data`` when divisible, so each data group runs the LMO
    for its own layer shard. Never applied to worker-dim leaves
    (``g_w``/``m_w``) — their leading dim already lives on the worker
    axis."""
    spec = param_pspec(meta, shape, mesh, fsdp=fsdp)
    data_n = mesh.shape.get("data", 1)
    if (meta.stack_dims >= 1 and data_n > 1 and len(shape) >= 1
            and shape[0] % data_n == 0
            and spec[0] is None and "data" not in spec):
        spec = P("data", *tuple(spec)[1:])
    return spec


def state_pspecs(state: dict, params: Any, metas: Any, mesh,
                 fsdp: bool = False, zero1_lmo: bool = False) -> dict:
    """PartitionSpecs for the full EF21-Muon optimizer state.

    * ``x`` / ``g_server`` / ``w``: the parameter rule (plus the zero-1
      layer-parallel rule when ``zero1_lmo``);
    * ``g_w`` / ``m_w`` / ``w_w`` (the §13 per-worker model estimates):
      leading worker dim on ``worker_axis_for(mesh)``, remaining dims
      follow the parameter rule;
    * ``step``: replicated; compressor states, the §13 resync
      version-vector/ring and anything else: replicated (they are
      sketches / PRNG keys / u8 rings, small by construction).

    Only leaf ``.shape`` attributes are read, so abstract states
    (ShapeDtypeStruct / eval_shape output) work.
    """
    treedef = jax.tree.structure(params)
    metas_l = treedef.flatten_up_to(metas)

    def map_like(tree, leaf_fn):
        leaves = treedef.flatten_up_to(tree)
        return treedef.unflatten(
            [leaf_fn(m, x.shape) for x, m in zip(leaves, metas_l)])

    out = {}
    for k, v in state.items():
        if v is None:
            out[k] = None
        elif k in ("x", "g_server", "w"):
            rule = _zero1_pspec if zero1_lmo else param_pspec
            out[k] = map_like(v, lambda m, s: rule(m, s, mesh, fsdp))
        elif k in ("g_w", "m_w", "w_w"):
            out[k] = map_like(v, lambda m, s: _worker_pspec(m, s, mesh, fsdp))
        elif k == "step":
            out[k] = P()
        else:  # cw_state / cs_state / future additions: replicate
            out[k] = jax.tree.map(lambda leaf: P(), v)
    return out


def ns_bucket_pspec(batch: int, shape: tuple[int, int],
                    member_specs, mesh, stack_model: bool = False) -> P:
    """PartitionSpec for one ``[batch, m, n]`` Newton-Schulz bucket stack
    (DESIGN.md §7): the sharding the batched spectral LMO chain runs
    under, so bucketing does not replicate compute the per-leaf path
    sharded.

    * the batch dim shards over the **largest divisible slow-axis
      composition**: ``data``, ``pod``, or ``("pod", "data")`` on
      multi-pod meshes — whichever has the most shards while dividing
      ``batch`` (the stack folds layer/expert stacks into the batch dim,
      so this subsumes the zero-1 layer-parallel rule and adds batch
      parallelism the per-leaf path never had). With ``stack_model``
      (some member carries its ``model`` axis on a *stack* dim —
      expert parallelism, whose expert dim is folded into the batch dim)
      the compositions may additionally include ``model``, provided the
      trailing dims left it free: expert-parallel stacks keep their
      model-axis parallelism as batch parallelism instead of being
      pinned replicated over ``model``;
    * the trailing dims carry ``model`` when **all** member leaves that
      are TP-sharded agree on the canonical position of their ``model``
      axis after the stacking transpose (``member_specs`` is the
      per-member ``(row, col)`` slice spec in canonical orientation) and
      that dim divides — a mixed up/down-projection bucket whose members
      disagree stays unsharded on the trailing dims and relies on batch
      parallelism alone.

    Only ``mesh.shape`` / ``mesh.axis_names`` are read (shape-only mesh
    stand-ins work). No mesh axis is ever assigned twice: the batch dim
    draws from {pod, data} (plus ``model`` only when ``stack_model``
    and the trailing dims don't use it), the trailing dims from
    {model} only.
    """
    model_n = mesh.shape.get("model", 1)
    row = col = None
    if model_n > 1:
        pos = {(0 if r == "model" else 1)
               for r, c in member_specs if "model" in (r, c)}
        if pos == {0} and shape[0] % model_n == 0:
            row = "model"
        elif pos == {1} and shape[1] % model_n == 0:
            col = "model"

    slow = [a for a in ("pod", "data")
            if a in mesh.axis_names and mesh.shape.get(a, 1) > 1]
    cands: list[tuple[str, ...]] = [(a,) for a in slow]
    if len(slow) == 2:
        cands.append(("pod", "data"))
    if stack_model and model_n > 1 and row is None and col is None:
        cands += [c + ("model",) for c in cands] + [("model",)]
    lead: tuple[str, ...] | None = None
    lead_n = 1
    for c in cands:
        n = 1
        for a in c:
            n *= mesh.shape[a]
        if batch % n == 0 and n > lead_n:
            lead, lead_n = c, n
    if lead is not None and len(lead) == 1:
        lead = lead[0]
    return P(lead, row, col)


def batch_pspec(batch: Any, mesh, kind: str) -> Any:
    """Input batch specs. Train batches carry ``[n_workers, per_worker,
    ...]`` leading dims: workers go on the worker axis, and on a
    multi-pod mesh the per-worker batch additionally shards over
    ``data``. Prefill/decode batches shard their leading batch dim over
    ``data``."""
    waxis = worker_axis_for(mesh)
    data_n = mesh.shape.get("data", 1)

    def one(x):
        shape = x.shape
        axes: list[str | None] = [None] * len(shape)
        if not shape:
            return P()
        if kind == "train":
            if mesh.shape.get(waxis, 1) > 1 and shape[0] % mesh.shape[waxis] == 0:
                axes[0] = waxis
            if waxis == "pod" and len(shape) > 1 and data_n > 1 \
                    and shape[1] % data_n == 0:
                axes[1] = "data"
        elif data_n > 1 and shape[0] % data_n == 0:
            axes[0] = "data"
        return P(*axes)

    return jax.tree.map(one, batch)


def serve_pspecs(cache: Any, batch: int, mesh, cache_alt: Any = None) -> Any:
    """Decode-cache specs: the batch dim shards over ``data``; the
    sequence dim — the largest remaining dim divisible by the ``model``
    axis — shards over ``model`` (long caches are the serving memory
    bottleneck). Everything else is replicated.

    Cache layouts differ per model family (transformers stack
    ``[L, B, ...]``, recurrent families nest batch deeper), so the batch
    dim is found exactly when ``cache_alt`` — the same cache tree built
    at any *other* batch size (e.g. ``model.cache_spec(batch + 1, len)``)
    — is given: it is the dim where the shapes differ. Without it, a
    size-match heuristic biased to the transformer ``[L, B, ...]`` layout
    is used."""
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1)

    def one(x, alt=None):
        shape = x.shape
        axes: list[str | None] = [None] * len(shape)
        if alt is not None:
            if len(alt.shape) != len(shape):
                raise ValueError(
                    f"serve_pspecs: cache/cache_alt leaf rank mismatch "
                    f"({shape} vs {alt.shape}) — the batch dim is found "
                    f"by elementwise shape diff, so both cache trees must "
                    f"come from the same cache_spec at different batch "
                    f"sizes")
            diff = [i for i, (s, t) in enumerate(zip(shape, alt.shape))
                    if s != t]
            b_i = diff[0] if diff else None
        else:
            cand = [i for i, s in enumerate(shape) if s == batch]
            b_i = cand[0] if cand else None
            if cand[:2] == [0, 1] and len(shape) >= 3:
                # [n_layers, batch, ...] with n_layers == batch: prefer
                # the conventional batch position — but only dim 1; a
                # later same-size dim (a square [B, T, B] state) does not
                # displace a genuine batch at dim 0
                b_i = 1
        if b_i is not None and data_n > 1 and batch % data_n == 0:
            axes[b_i] = "data"
        cand = [(s, i) for i, s in enumerate(shape)
                if axes[i] is None and model_n > 1 and s > 1
                and s % model_n == 0]
        if cand:
            axes[max(cand)[1]] = "model"
        return P(*axes)

    if cache_alt is not None:
        return jax.tree.map(one, cache, cache_alt)
    return jax.tree.map(one, cache)


def to_shardings(specs: Any, mesh) -> Any:
    """Materialise a tree of PartitionSpecs into NamedShardings."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
