"""Desynchronized-worker rejoin: versioned W resync over the wire
(DESIGN.md §13).

PR 8 made the step survive *within-step* faults, but a worker absent
across a server->worker (s2w) broadcast silently drifts: its model
estimate W is stale forever after, which breaks both the EF21-P
sender/receiver invariant (§2) and the Gluon-FL partial-participation
contraction argument the elastic extension (§11) relies on. This module
is the rejoin algebra that closes that gap, all of it in-graph:

  * a per-worker **version vector** ``vv`` (``[n_workers]`` int32):
    ``vv[j]`` is the number of s2w rounds worker j has applied, i.e. the
    next round it needs. Advanced by the reception mask each step;
    frozen for absent workers.
  * a bounded **replay ring buffer** of the last R packed s2w broadcast
    rounds (``[R, total_s2w_nbytes]`` uint8 — the ``wire/layout.py``
    bytes verbatim, stage sub-buffers concatenated in stage order). The
    ring is roll-pushed every round, so after the push slot ``r``
    statically holds round ``step - (R-1) + r`` and slot ``R-1`` is the
    current round.
  * the **replay masks**: a rejoining worker with lag <= R catches up by
    replaying the missed rounds through the exact ``apply_payload``
    algebra (decompress once per slot, shared across workers — the
    broadcast was one message), in ascending round order, which is
    bit-identical to having applied each round on time. A worker with
    lag > R takes a **full W resync**: a bit-copy of the server's
    post-round W (in-graph for live processes; a fresh process is served
    the same tree through the atomic-checkpoint machinery,
    ``serve_full_resync``).

Reception semantics: the mask that advances ``vv`` is the *scheduled*
participation mask AND the fault drop mask — network-level reception.
Guard demotion (§11) does NOT gate it: a worker whose payload went
non-finite still heard the broadcast (its compute is poisoned, not its
downlink). Skipped steps (all workers demoted) still advance the
ring/vv/W estimates, consistent with the server's W advancing on skip.

Everything here is mask algebra over static shapes: replay adds NO new
collectives (the ring is replicated, decompression is local), so the
§8/§9 exact-2K-u8-gather wire invariants hold unchanged under a
drop -> rejoin -> replay cycle (pinned in ``tests/test_sharding.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


# XLA shape dims are signed-int32-bounded on the paths the ring hits; a
# packed s2w row past this is also far past any sane replicated buffer
_MAX_RING_ROW_NBYTES = 2**31 - 1


def resolve_ring_depth(resync: Any) -> int:
    """The resolved replay-ring depth R: ``None``/``0``/``False`` turn
    the subsystem off (returns 0 — the lowering-identical default arm);
    an int >= 1 is the bound R on replayable lag."""
    if resync is None or resync is False or resync == 0:
        return 0
    r = int(resync)
    if r < 1:
        raise ValueError(f"resync ring depth must be >= 1, got {resync!r}")
    return r


def init_resync_state(n_workers: int, ring_depth: int,
                      row_nbytes: int) -> dict:
    """Fresh resync state: all-zero version vector (every worker needs
    round 0 next) and a zeroed ring. Zero-filled slots are never applied:
    at step t slot r holds round ``t - (R-1) + r`` and the replay mask
    requires ``round >= vv[j] >= 0``, so pre-history (negative) rounds
    are masked out by construction."""
    if row_nbytes > _MAX_RING_ROW_NBYTES:
        raise ValueError(
            f"resync ring row ({row_nbytes} packed s2w bytes) exceeds the "
            f"XLA dimension limit ({_MAX_RING_ROW_NBYTES}); the replicated "
            "in-graph ring is not viable at this model scale — serve "
            "rejoining workers a full W resync out-of-process instead "
            "(dist.resync.serve_full_resync over the checkpoint archive)")
    return {
        "vv": jnp.zeros((n_workers,), jnp.int32),
        "ring": jnp.zeros((ring_depth, row_nbytes), jnp.uint8),
    }


def ring_push(ring: jax.Array, row: jax.Array) -> jax.Array:
    """Roll-push ``row`` (the current round's packed s2w bytes) into the
    ring: oldest slot falls off the front, the new round lands in slot
    R-1. Static slot indexing — slot r always holds round
    ``step - (R-1) + r`` after the push."""
    return jnp.concatenate([ring[1:], row[None].astype(jnp.uint8)], axis=0)


@dataclass(frozen=True)
class ReplayMasks:
    """The per-step rejoin decision, all ``[n_workers]``-shaped algebra.

    ``apply[r, j]`` — replay ring slot r into worker j's W estimate
    (slots are applied in ascending r == ascending round order);
    ``full[j]`` — worker j rejoins with lag > R and takes the full
    W copy instead; ``vv_new`` — the advanced version vector. The
    count/lag scalars feed §10 telemetry and the step ``aux``."""
    apply: jax.Array       # [R, n_workers] bool
    full: jax.Array        # [n_workers] bool
    vv_new: jax.Array      # [n_workers] int32
    n_replayed: jax.Array  # workers that caught up via replay (lag >= 1)
    n_full: jax.Array      # workers that took the full W resync
    lag_max: jax.Array     # max post-update version lag across workers


def replay_masks(vv: jax.Array, step, recv: jax.Array,
                 ring_depth: int) -> ReplayMasks:
    """The rejoin masks for one round.

    ``vv`` is the version vector BEFORE this round, ``step`` the (traced)
    round counter, ``recv`` the reception mask for this round's
    broadcast. After the ring push, slot r holds round
    ``step - (R-1) + r``; worker j is *replayable* iff its next needed
    round is still in the ring (``vv[j] >= step - (R-1)``), in which
    case it applies every slot with ``round >= vv[j]`` — an always-
    current worker (``vv == step``) applies exactly the current round,
    so on-time application is the degenerate replay."""
    r = int(ring_depth)
    step = jnp.asarray(step, jnp.int32)
    vv = jnp.asarray(vv, jnp.int32)
    rounds = step - (r - 1) + jnp.arange(r, dtype=jnp.int32)
    replayable = vv >= step - (r - 1)
    apply = (recv[None, :] & replayable[None, :]
             & (rounds[:, None] >= vv[None, :]))
    full = recv & ~replayable
    vv_new = jnp.where(recv, step + 1, vv)
    n_replayed = jnp.sum(
        (recv & replayable & (vv < step)).astype(jnp.int32))
    n_full = jnp.sum(full.astype(jnp.int32))
    lag_max = jnp.max((step + 1) - vv_new).astype(jnp.int32)
    return ReplayMasks(apply=apply, full=full, vv_new=vv_new,
                       n_replayed=n_replayed, n_full=n_full,
                       lag_max=lag_max)


def serve_full_resync(path: str, state_like: Any) -> tuple[Any, int]:
    """Serve a fresh-process rejoin from the atomic checkpoint
    (``train/checkpoint.py``): loads the last-good generation (with the
    ``.prev`` fallback and checksum verification that machinery
    provides) and returns ``(w_tree, version)`` — the server's model
    estimate W (falling back to the iterate X for identity-s2w configs,
    where W == X by construction) and the step it is current at. The
    caller installs the tree as the rejoining worker's ``w_w[j]`` row
    and sets ``vv[j] = version``; from there the in-graph replay path
    takes over."""
    from repro.train.checkpoint import load_checkpoint
    state, step = load_checkpoint(path, state_like)
    if not isinstance(state, dict) or "x" not in state:
        raise ValueError(
            f"{path}: not an optimizer-state checkpoint (no 'x' entry)")
    w = state["w"] if state.get("w") is not None else state["x"]
    return w, int(step or 0)
