"""Stage assignment for the staged wire pipeline (DESIGN.md §8).

The monolithic wire buffer (§6) serialises ONE payload all-gather ahead
of all phase-5 LMO compute, so none of the gather latency is hidden —
even though the batched Newton-Schulz chains (§7) are exactly the
long-running, communication-free compute that could hide it. This module
partitions the plan's leaves into K *wire stages* aligned with the NS
buckets that consume them:

  * stage 0 is the **eager** chunk: every leaf the per-leaf phase-5 path
    handles (non-spectral leaves, spectral leaves without a 2-D slice) —
    cheap sign/vector LMOs consumed first;
  * every NS bucket gets a stage, ordered **descending by NS FLOPs**:
    the biggest batched chains run first, so their compute hides the
    still-in-flight gathers of the later stages (all K gathers are
    issued up front by the optimizer — see ``core/muon.py`` phase 4);
  * ``wire_stages=N`` caps the stage count: the smallest-FLOP buckets
    merge into the last stage (N == 1 collapses to the monolithic path,
    the bit-identical A/B arm; ``"auto"`` keeps one stage per bucket).

A stage is a pure *repartition* of the §6 buffer: the per-stage
sub-buffers of ``wire.layout.StagedWireLayout`` sum byte-for-byte to
``WireLayout.total_nbytes`` and every leaf keeps its codec byte-layout,
so pack -> unpack stays bit-exact per stage and the staged step is
value-bit-equal to the monolithic one on the jnp path.

The s2w direction (DESIGN.md §9) reuses the SAME leaf partition: the
server's model-update broadcast is cut into the identical K stage
sub-buffers (built from the ``lp.s2w`` codecs), so each stage's w2s
gather and s2w broadcast pair up 1:1 and the two-direction byte
invariant stays a per-stage statement. Only the *issue order* differs —
``s2w_issue_order`` ranks stages by decompress/apply work (the compute
that consumes the broadcast) rather than NS FLOPs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def bucket_ns_flops(bucket, ns_steps: int = 5) -> float:
    """Static FLOP estimate of one bucket's batched Newton-Schulz chain:
    per slice and iteration, the gram ``X Xᵀ`` (2·m²·n), the quintic
    polynomial ``A²`` (2·m³) and the update ``poly @ X`` (2·m²·n). Only
    used to *order* stages, so the constant factor is irrelevant."""
    m, n = bucket.shape
    return float(ns_steps) * bucket.batch * (4.0 * m * m * n + 2.0 * m ** 3)


@dataclass(frozen=True)
class WireStage:
    """One stage of the pipeline: which plan leaves ride its sub-buffer
    and which NS buckets its unpack feeds."""
    leaf_ids: tuple[int, ...]      # plan-leaf ids, treedef order
    bucket_ids: tuple[int, ...]    # indices into plan.ns_buckets(...)
    ns_flops: float                # static NS FLOPs this stage runs


@dataclass(frozen=True)
class StagePlan:
    """Leaf -> stage partition of a LayerPlan (built once per plan and
    (mesh shape, fsdp, wire_stages) via ``LayerPlan.stage_plan``)."""
    stages: tuple[WireStage, ...]
    eager_leaf_ids: tuple[int, ...]   # stage-0 per-leaf-path leaves

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def leaf_to_stage(self) -> dict[int, int]:
        return {i: k for k, s in enumerate(self.stages) for i in s.leaf_ids}


def build_stage_plan(plan, buckets, wire_stages="auto",
                     ns_steps: int = 5) -> StagePlan:
    """Partition ``plan``'s leaves into wire stages along the NS buckets.

    ``buckets`` is ``plan.ns_buckets(mesh, fsdp)`` — each bucket's leaves
    land in exactly one stage, so the batched LMO of a stage consumes
    only its own sub-buffer. ``wire_stages``: ``"auto"`` = one stage per
    bucket plus the eager chunk; an int ``N >= 1`` caps the count by
    merging the smallest-FLOP bucket stages into the last one (``N`` can
    never split a bucket, so the effective count is ``min(N, auto)``).

    Deterministic: bucket stages descend by ``bucket_ns_flops`` (ties
    break on bucket index); the union of stage ``leaf_ids`` is exactly
    ``range(len(plan.leaves))`` with no leaf assigned twice.
    """
    if wire_stages != "auto":
        wire_stages = int(wire_stages)
        if wire_stages < 1:
            raise ValueError(f"wire_stages must be >= 1, got {wire_stages}")
    bucketed = {i for b in buckets for i in b.leaf_ids}
    eager = tuple(i for i in range(len(plan.leaves)) if i not in bucketed)
    order = sorted(range(len(buckets)),
                   key=lambda bi: (-bucket_ns_flops(buckets[bi], ns_steps),
                                   bi))
    stages: list[WireStage] = []
    if eager:
        stages.append(WireStage(leaf_ids=eager, bucket_ids=(), ns_flops=0.0))
    for bi in order:
        b = buckets[bi]
        stages.append(WireStage(leaf_ids=tuple(sorted(b.leaf_ids)),
                                bucket_ids=(bi,),
                                ns_flops=bucket_ns_flops(b, ns_steps)))
    if wire_stages != "auto" and len(stages) > wire_stages:
        # merge the smallest-FLOP tail (bucket stages are already sorted
        # descending; the eager stage, if present, stays stage 0)
        head, tail = stages[:wire_stages - 1], stages[wire_stages - 1:]
        merged = WireStage(
            leaf_ids=tuple(sorted(i for s in tail for i in s.leaf_ids)),
            bucket_ids=tuple(bi for s in tail for bi in s.bucket_ids),
            ns_flops=sum(s.ns_flops for s in tail))
        stages = head + [merged]
    return StagePlan(stages=tuple(stages), eager_leaf_ids=eager)


def s2w_issue_order(plan, stage_plan: StagePlan) -> tuple[int, ...]:
    """Issue order of the K s2w broadcast sub-buffers (DESIGN.md §9).

    The s2w leg reuses ``stage_plan``'s leaf partition, but the compute
    that hides a broadcast is its *receive* chain — per-leaf decompress
    + apply_payload, proportional to leaf elements — not the NS FLOPs
    that ordered the w2s stages. Broadcasts are issued descending by
    that receive work, so the heaviest reconstruction overlaps the
    still-in-flight broadcasts of the later stages. Deterministic (ties
    break on stage index); always a permutation of ``range(n_stages)``.
    """
    def receive_work(stage: WireStage) -> float:
        return float(sum(math.prod(plan.leaves[i].shape)
                         for i in stage.leaf_ids))

    return tuple(sorted(
        range(stage_plan.n_stages),
        key=lambda k: (-receive_work(stage_plan.stages[k]), k)))
