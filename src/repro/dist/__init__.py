# Distribution layer: mesh partition rules + layer-wise optimizer plumbing.
from .bucketing import NSBucket, build_buckets
from .layerwise import LayerPlan, LeafPlan, resolve_compressor, vmap_n
from .participation import (Explicit, mask_bcast, participation_mask,
                            payload_finite_mask, validate_spec)
from .pipeline import StagePlan, WireStage, bucket_ns_flops, build_stage_plan
from .sharding import (batch_pspec, n_workers_for, ns_bucket_pspec,
                       param_pspec, param_pspecs, serve_pspecs, state_pspecs,
                       to_shardings, worker_axis_for)

__all__ = [
    "LayerPlan", "LeafPlan", "resolve_compressor", "vmap_n",
    "NSBucket", "build_buckets", "ns_bucket_pspec",
    "StagePlan", "WireStage", "bucket_ns_flops", "build_stage_plan",
    "param_pspec", "param_pspecs", "state_pspecs", "batch_pspec",
    "serve_pspecs", "to_shardings", "worker_axis_for", "n_workers_for",
    "Explicit", "participation_mask", "payload_finite_mask",
    "validate_spec", "mask_bcast",
]
