"""Elastic per-step worker participation (DESIGN.md §11).

EF21-Muon's worker-axis all-gather assumes every worker shows up every
step; at production scale workers straggle, die, and emit NaNs. The
*Communication-Efficient Gluon in Federated Learning* analysis gives the
partial-participation recipe with error feedback: a worker that skips a
round simply FREEZES its EF21 error state (G_j, momentum, compressor
sketches) — the contraction argument needs exactly this — while the
server folds only the participants, normalised by the *dynamic*
participant count.

This module is the static/schedule half of that story:

  * ``participation_mask(spec, n, step, seed)`` — the per-step
    ``[n_workers]`` bool mask, computed IN-GRAPH from the (traced) step
    counter, so the jitted step stays a single static-shape program: the
    staged u8 gathers still move every worker's payload (same K
    collectives, same bytes — the §6/§8/§9 wire invariants are
    untouched) and absence is applied at fold/commit time via
    ``where``-masking.
  * ``payload_finite_mask(payloads, n)`` — the non-finite guard: a
    per-worker finiteness reduction over the float leaves of the
    (post-unpack) payload pytrees. A worker whose payload carries
    NaN/Inf — a poisoned gradient, a torn wire buffer — is auto-demoted
    to non-participating for the step, so the poison never enters
    ``g_server`` or the worker's own EF21 state.

Schedules (``spec`` is a string or an ``Explicit`` instance):

  ``"full"``            every worker, every step (the bit-equal arm —
                        the optimizer skips the masked path entirely)
  ``"bernoulli(p)"``    each worker participates i.i.d. w.p. ``p`` per
                        step, seeded + step-keyed => deterministic and
                        resume-stable
  ``"round_robin(k)"``  a rotating contiguous window of ``k`` workers
  ``Explicit(masks)``   an explicit mask table, indexed ``step % len``
                        (the fault-injection / test override)

All schedules may yield an all-zero mask (bernoulli genuinely, Explicit
by construction); the optimizer's skip-step fallback handles it.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp

_BERNOULLI_RE = re.compile(r"^bernoulli\(([0-9.eE+-]+)\)$")
_ROUND_ROBIN_RE = re.compile(r"^round_robin\(([0-9]+)\)$")


@dataclass(frozen=True)
class Explicit:
    """Explicit per-step mask table: ``masks[step % len(masks)]``.

    ``masks`` is a tuple of length-``n_workers`` tuples of 0/1 — static
    data, so the whole table becomes one constant in the graph and tests
    can pin exactly which worker is absent at which step."""
    masks: tuple

    def __post_init__(self):
        if not self.masks:
            raise ValueError("Explicit participation needs >= 1 mask")
        n = len(self.masks[0])
        if any(len(m) != n for m in self.masks):
            raise ValueError("Explicit masks must all have the same length")


def validate_spec(spec, n_workers: int) -> None:
    """Raise ValueError on a malformed participation spec (called once
    at step-build time, so CLI typos fail fast, not at trace time)."""
    if isinstance(spec, Explicit):
        if len(spec.masks[0]) != n_workers:
            raise ValueError(
                f"Explicit masks are for {len(spec.masks[0])} workers, "
                f"optimizer has {n_workers}")
        return
    if not isinstance(spec, str):
        raise ValueError(f"participation spec must be str or Explicit, "
                         f"got {type(spec).__name__}")
    if spec == "full":
        return
    m = _BERNOULLI_RE.match(spec)
    if m:
        p = float(m.group(1))
        if not 0.0 < p <= 1.0:
            raise ValueError(f"bernoulli(p) needs 0 < p <= 1, got {p}")
        return
    m = _ROUND_ROBIN_RE.match(spec)
    if m:
        k = int(m.group(1))
        if not 1 <= k <= n_workers:
            raise ValueError(
                f"round_robin(k) needs 1 <= k <= {n_workers}, got {k}")
        return
    raise ValueError(
        f"unknown participation spec {spec!r}; expected 'full', "
        f"'bernoulli(p)', 'round_robin(k)' or an Explicit mask table")


def participation_mask(spec, n_workers: int, step, seed: int = 0):
    """The ``[n_workers]`` bool participation mask for ``step`` (a traced
    or concrete int32 scalar). Deterministic in (spec, seed, step) — a
    resumed run replays the identical participation history."""
    if isinstance(spec, Explicit):
        table = jnp.asarray(spec.masks, jnp.bool_)
        return table[jnp.mod(jnp.asarray(step, jnp.int32), table.shape[0])]
    if spec == "full":
        return jnp.ones((n_workers,), jnp.bool_)
    m = _BERNOULLI_RE.match(spec)
    if m:
        key = jax.random.fold_in(jax.random.key(seed),
                                 jnp.asarray(step, jnp.int32))
        return jax.random.bernoulli(key, float(m.group(1)), (n_workers,))
    m = _ROUND_ROBIN_RE.match(spec)
    if m:
        k = int(m.group(1))
        # rotating contiguous window: step s keeps workers
        # {(s*k + i) mod n : i < k} — every worker participates k/n of
        # the time and the window advances by k each step
        start = jnp.mod(jnp.asarray(step, jnp.int32) * k, n_workers)
        offset = jnp.mod(jnp.arange(n_workers, dtype=jnp.int32) - start,
                         n_workers)
        return offset < k
    raise ValueError(f"unknown participation spec {spec!r}")


def reception_mask(spec, n_workers: int, step, seed: int = 0,
                   faults=None):
    """The ``[n_workers]`` *reception* mask for ``step`` — the §13
    resync semantics: a worker "heard" this round's s2w broadcast iff it
    was scheduled to participate AND no drop fault severed its link.
    Guard demotion deliberately does NOT gate this (a worker whose
    payload went non-finite has poisoned compute, not a dead downlink),
    which is why reception is computable *before* the gradients exist —
    the version vector and replay ring (``dist/resync.py``) advance on
    it."""
    mask = participation_mask(spec, n_workers, step, seed)
    if faults is not None:
        mask = mask & faults.drop_mask(step)
    return mask


def payload_finite_mask(payloads, n_workers: int):
    """Per-worker payload finiteness: ``[n_workers]`` bool, False for any
    worker whose payload carries a non-finite float anywhere.

    ``payloads`` is the optimizer's flat per-leaf list of payload pytrees,
    each leaf ``[n_workers, ...]`` (worker-lead). Only inexact leaves are
    checked — integer index/code leaves cannot encode NaN (a bit-flipped
    index decodes to a wrong-but-finite scatter, which the EF21 feedback
    loop absorbs like any other finite compression error)."""
    flags = jnp.ones((n_workers,), jnp.bool_)
    for pl in payloads:
        for leaf in jax.tree.leaves(pl):
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                continue
            axes = tuple(range(1, leaf.ndim))
            flags = flags & jnp.all(jnp.isfinite(
                leaf.astype(jnp.float32)), axis=axes)
    return flags


def mask_bcast(mask, ndim: int):
    """Reshape a ``[n]`` mask to broadcast against a worker-lead
    ``[n, ...]`` array of rank ``ndim``."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))
