"""Layer-wise tree plumbing for the EF21-Muon optimizer.

The optimizer is layer-wise by construction: every phase of a step
(EF21-P model shift, worker EF21 compression, server decompression, the
LMO update) is "for each parameter leaf: resolve its compressor, strip
its stack dims, vmap a per-slice function". A ``LayerPlan`` precomputes
all of that once per (treedef, metas, shapes) so the optimizer states
algorithm steps instead of tree mechanics:

    plan = LayerPlan.build(params, metas, w2s="rank10", s2w="natural")
    new_x = plan.map_leaves(lmo_leaf, x_tree, g_tree)          # stack-vmapped
    outs  = plan.map_flat(ef_leaf, cw_l, gw_l, m_l, extra_vmap=1)  # + worker dim

Compressor resolution rule (deterministic, documented here once):
rank-type compressors (RankK, TopKSVD — with or without a Natural
wrapper) need a matrix slice; on a non-2D slice they fall back to
``TopK(0.25)``, keeping the Natural wrapper if one was requested. Such
leaves are vectors/scalars and contribute negligible wire bytes, so the
fallback fraction is not performance-relevant — but it is deterministic
and independent of the compressor *name*, unlike string sniffing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.obs.trace import span


def vmap_n(fn: Callable, n: int) -> Callable:
    """vmap ``fn`` over the ``n`` leading (stack) dims of its args."""
    for _ in range(n):
        fn = jax.vmap(fn)
    return fn


def resolve_compressor(name: str, slice_shape: tuple[int, ...]):
    """Instantiate the compressor for one leaf slice (see module
    docstring for the non-2D fallback rule)."""
    # Deferred import: repro.core.muon (pulled in by repro.core.__init__)
    # imports this module, so a top-level core import would be circular.
    from repro.core import compressors as comp_lib

    comp = comp_lib.get_compressor(name)
    inner = comp.inner if isinstance(comp, comp_lib.WithNatural) else comp
    if isinstance(inner, (comp_lib.RankK, comp_lib.TopKSVD)) \
            and len(slice_shape) != 2:
        fallback = comp_lib.TopK(0.25)
        if isinstance(comp, comp_lib.WithNatural):
            return comp_lib.WithNatural(fallback)
        return fallback
    return comp


@dataclass(frozen=True)
class LeafPlan:
    """Everything static about one parameter leaf."""
    meta: Any                       # ParamMeta-like
    shape: tuple[int, ...]          # full leaf shape (no worker dim)
    stack_shape: tuple[int, ...]    # leading stack dims
    slice_shape: tuple[int, ...]    # per-layer operand the LMO/compressor sees
    n_stack: int                    # prod(stack_shape)
    w2s: Any                        # resolved worker->server compressor
    s2w: Any                        # resolved server->worker compressor


class LayerPlan:
    """Per-(treedef, metas, shapes) plan shared by every optimizer phase."""

    def __init__(self, treedef, leaves: list[LeafPlan]):
        self.treedef = treedef
        self.leaves = leaves
        self._wire_layouts: dict = {}   # (dtype name, direction) -> WireLayout
        self._ns_buckets: dict = {}     # (mesh key, fsdp) -> tuple[NSBucket]
        self._stage_plans: dict = {}    # (mesh key, fsdp, stages) -> StagePlan
        self._staged_layouts: dict = {}  # (dtype, stage ids, direction)
        #                                  -> StagedWireLayout

    @classmethod
    def build(cls, params: Any, metas: Any, w2s: str = "identity",
              s2w: str = "identity") -> "LayerPlan":
        """``params`` may be concrete arrays, ShapeDtypeStructs or
        tracers — only ``.shape`` is read. ``metas`` mirrors the params
        tree with ParamMeta leaves; incompressible leaves get identity
        compressors in both directions."""
        with span("plan/build"):
            leaves, treedef = jax.tree.flatten(params)
            metas_l = treedef.flatten_up_to(metas)
            plans = []
            for p, m in zip(leaves, metas_l):
                shape = tuple(p.shape)
                stack = shape[:m.stack_dims]
                sshape = shape[m.stack_dims:]
                wname = w2s if m.compressible else "identity"
                sname = s2w if m.compressible else "identity"
                plans.append(LeafPlan(
                    meta=m, shape=shape, stack_shape=stack, slice_shape=sshape,
                    n_stack=int(math.prod(stack)) if stack else 1,
                    w2s=resolve_compressor(wname, sshape),
                    s2w=resolve_compressor(sname, sshape)))
            return cls(treedef, plans)

    # ------------------------------------------------------------- tree ops
    def flatten(self, tree: Any) -> list:
        return self.treedef.flatten_up_to(tree)

    def unflatten(self, leaves: list) -> Any:
        return self.treedef.unflatten(leaves)

    def map_flat(self, fn: Callable, *flat: list, extra_vmap: int = 0) -> list:
        """``fn(leaf_plan, *slices)`` applied per leaf, vmapped over the
        leaf's stack dims plus ``extra_vmap`` extra leading dims (e.g. 1
        for the worker dimension). Inputs and output are flat lists in
        treedef order; tuple-valued ``fn`` results stay zipped per leaf."""
        out = []
        for lp, *xs in zip(self.leaves, *flat):
            out.append(vmap_n(partial(fn, lp),
                              lp.meta.stack_dims + extra_vmap)(*xs))
        return out

    def map_leaves(self, fn: Callable, *trees: Any,
                   extra_vmap: int = 0) -> Any:
        """Tree-in/tree-out version of ``map_flat``."""
        return self.unflatten(self.map_flat(
            fn, *[self.flatten(t) for t in trees], extra_vmap=extra_vmap))

    # ------------------------------------------------------ wire accounting
    def w2s_bytes_per_worker(self, wire_dtype) -> int:
        """Static bytes of one worker->server message (Table 2): the sum
        over leaves of stack-count x per-slice payload bytes. The single
        source of truth for wire accounting — the CLI and benchmarks read
        from here."""
        return sum(lp.n_stack * lp.w2s.payload_bytes(lp.slice_shape, wire_dtype)
                   for lp in self.leaves)

    def s2w_bytes_per_round(self, wire_dtype) -> int:
        """Static bytes of one server->worker model-update broadcast
        (the EF21-P / C_P direction, same Table-2 accounting convention
        as ``w2s_bytes_per_worker``). One message per round — the
        server broadcasts a single compressed S = C_P(X - W)."""
        return sum(lp.n_stack * lp.s2w.payload_bytes(lp.slice_shape, wire_dtype)
                   for lp in self.leaves)

    def dense_bytes(self, wire_dtype) -> int:
        """Uncompressed wire cost of the same message."""
        return dense_payload_bytes((lp.shape for lp in self.leaves),
                                   wire_dtype)

    # ------------------------------------------------------- NS bucketing
    def ns_buckets(self, mesh=None, fsdp: bool = False) -> tuple:
        """Shape buckets over the spectral leaves (DESIGN.md §7) — the
        static grouping behind the batched Newton-Schulz dispatch in
        phase 5 of the optimizer. With ``mesh`` each bucket also carries
        its ``ns_bucket_pspec`` (the sharding of the stacked chain).
        Built once per plan and (mesh shape, fsdp) combination."""
        from repro.dist.bucketing import build_buckets

        key = None if mesh is None else (
            tuple(mesh.axis_names),
            tuple(mesh.shape[a] for a in mesh.axis_names), fsdp)
        if key not in self._ns_buckets:
            with span("plan/ns_buckets"):
                self._ns_buckets[key] = build_buckets(self, mesh=mesh,
                                                      fsdp=fsdp)
        return self._ns_buckets[key]

    # ------------------------------------------------------- wire staging
    def stage_plan(self, mesh=None, fsdp: bool = False, wire_stages="auto",
                   ns_steps: int = 5):
        """The staged-wire-pipeline partition of this plan's leaves
        (DESIGN.md §8): stage 0 carries the per-leaf-path (eager) leaves,
        then one stage per NS bucket descending by NS FLOPs, capped at
        ``wire_stages`` by merging the smallest tail. Built once per
        (mesh shape, fsdp, wire_stages)."""
        from repro.dist.pipeline import build_stage_plan

        mesh_key = None if mesh is None else (
            tuple(mesh.axis_names),
            tuple(mesh.shape[a] for a in mesh.axis_names))
        key = (mesh_key, fsdp, wire_stages, ns_steps)
        if key not in self._stage_plans:
            with span("plan/stage_plan"):
                self._stage_plans[key] = build_stage_plan(
                    self, self.ns_buckets(mesh=mesh, fsdp=fsdp),
                    wire_stages=wire_stages, ns_steps=ns_steps)
        return self._stage_plans[key]

    def staged_wire_layout(self, wire_dtype, stage_plan,
                           direction: str = "w2s"):
        """The ``StagedWireLayout`` repartitioning ``wire_layout`` along
        ``stage_plan`` — memoised per (wire dtype, stage partition,
        direction). Both directions reuse the *same* leaf partition, so
        the s2w broadcasts pair 1:1 with the w2s gathers per stage."""
        from repro.wire.layout import build_staged_layout

        ids = tuple(s.leaf_ids for s in stage_plan.stages)
        key = (jnp.dtype(wire_dtype).name, ids, direction)
        if key not in self._staged_layouts:
            with span("plan/staged_wire_layout"):
                self._staged_layouts[key] = build_staged_layout(
                    self.wire_layout(wire_dtype, direction=direction), ids)
        return self._staged_layouts[key]

    def wire_layout(self, wire_dtype, direction: str = "w2s"):
        """The static WireLayout (repro.wire) for this plan and
        direction, memoised per (wire dtype, direction): the offset
        table of the fused per-worker payload buffer (``"w2s"``) or of
        the server's model-update broadcast message (``"s2w"``, §9).
        ``wire_layout(d, dir).total_nbytes`` is the *exact* byte count
        that direction's u8 collective moves — compare with the
        analytic Table-2 ``w2s_bytes_per_worker`` /
        ``s2w_bytes_per_round`` (which keep the paper's 4-byte-index
        convention)."""
        # Deferred import: repro.wire.layout imports this module.
        from repro.wire.layout import build_layout

        key = (jnp.dtype(wire_dtype).name, direction)
        if key not in self._wire_layouts:
            with span("plan/wire_layout"):
                self._wire_layouts[key] = build_layout(self, wire_dtype,
                                                       direction=direction)
        return self._wire_layouts[key]


def dense_payload_bytes(shapes, wire_dtype) -> int:
    """Wire bytes of an uncompressed message over the given leaf shapes —
    the one dense-accounting rule (LayerPlan and EF21Muon both call it)."""
    itemsize = jnp.dtype(wire_dtype).itemsize
    return sum(int(math.prod(s)) * itemsize for s in shapes)
