# Wire-format subsystem: lowers each worker's per-step payload pytree
# into one contiguous uint8 buffer with a static offset table, so the
# w2s all-gather moves exactly the accounted bytes in one collective
# (DESIGN.md §6).
from .codecs import NarrowIntCodec, RawCodec, index_domains, leaf_codecs
from .layout import WireLayout, WireSpec, build_layout

__all__ = ["RawCodec", "NarrowIntCodec", "leaf_codecs", "index_domains",
           "WireSpec", "WireLayout", "build_layout"]
