"""Static wire layout: the whole per-direction message as ONE uint8
buffer with a precomputed offset table (DESIGN.md §6, §9).

Built once per (LayerPlan, wire dtype, direction) — the payload
structure of every leaf is derived abstractly (``jax.eval_shape`` over
the resolved compressor's ``init``/``compress``), so construction
allocates nothing and is safe inside a traced step.

Buffer layout, per message:

    [ leaf 0: stack slice 0 | stack slice 1 | ... ][ leaf 1: ... ] ...

Each slice region is the concatenation of that compressor's payload
leaves, each encoded by its codec (see ``codecs.py``).  ``pack`` maps
codecs over the lead + stack dims with the same ``vmap_n`` discipline
as every other optimizer phase, producing a ``[lead, total_nbytes]``
buffer.  The lead dim is the message multiplicity: ``n_workers``
independent messages for the w2s direction (replicating that buffer
over the worker mesh axis is the fused payload all-gather of the
step), and ``1`` for the s2w direction (the server's single broadcast
message, §9).  ``unpack`` is the bit-exact inverse, so the EF21/EF21-P
sender/receiver invariant survives the wire in both directions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.layerwise import vmap_n

from .codecs import leaf_codecs


def _payload_struct(comp: Any, slice_shape: tuple[int, ...], in_dtype):
    """Abstract payload of one slice: eval_shape over init + compress."""
    def one(key):
        x = jnp.zeros(slice_shape, in_dtype)
        state = comp.init(key, slice_shape, in_dtype)
        payload, _ = comp.compress(state, x)
        return payload

    return jax.eval_shape(one, jax.random.key(0))


@dataclass(frozen=True)
class WireSpec:
    """Everything static about one parameter leaf's wire region."""
    offset: int                     # byte offset of the leaf region
    slice_nbytes: int               # packed bytes of ONE stack slice
    stack_shape: tuple[int, ...]
    n_stack: int
    codec_id: str                   # human-readable codec summary
    treedef: Any                    # payload treedef of one slice
    codecs: tuple                   # per payload leaf, flatten order
    splits: tuple[int, ...]         # byte offsets of payload leaves

    @property
    def region_nbytes(self) -> int:
        return self.n_stack * self.slice_nbytes

    # --------------------------------------------------- slice pack pair
    def pack_slice(self, payload: Any) -> jax.Array:
        leaves = self.treedef.flatten_up_to(payload)
        parts = [c.pack(x) for c, x in zip(self.codecs, leaves)]
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def unpack_slice(self, buf: jax.Array) -> Any:
        leaves = [c.unpack(jax.lax.slice_in_dim(buf, o, o + c.nbytes))
                  for c, o in zip(self.codecs, self.splits)]
        return self.treedef.unflatten(leaves)


@dataclass(frozen=True)
class WireLayout:
    """Offset table + pack/unpack for the full per-step message."""
    specs: tuple[WireSpec, ...]     # aligned with LayerPlan.leaves
    total_nbytes: int               # exact bytes of one message
    direction: str = "w2s"          # which compressor family laid it out

    # ------------------------------------------------------ message pack
    def pack(self, flat_payloads: list) -> jax.Array:
        """Flat per-leaf payload list (leaves ``[lead, *stack, ...]`` —
        lead is ``n_workers`` for w2s, exactly as
        ``LayerPlan.map_flat(..., extra_vmap=1)`` produces them, or 1
        for the s2w broadcast message) -> ``[lead, total_nbytes]``
        uint8 buffer."""
        parts = []
        for spec, payload in zip(self.specs, flat_payloads):
            packed = vmap_n(spec.pack_slice,
                            len(spec.stack_shape) + 1)(payload)
            parts.append(packed.reshape(packed.shape[0], -1))
        return jnp.concatenate(parts, axis=1)

    def unpack(self, buf: jax.Array) -> list:
        """Bit-exact inverse of ``pack`` (same flat-list convention)."""
        n_workers = buf.shape[0]
        out = []
        for spec in self.specs:
            seg = jax.lax.slice_in_dim(
                buf, spec.offset, spec.offset + spec.region_nbytes, axis=1)
            seg = seg.reshape((n_workers,) + spec.stack_shape
                              + (spec.slice_nbytes,))
            out.append(vmap_n(spec.unpack_slice,
                              len(spec.stack_shape) + 1)(seg))
        return out

    # ------------------------------------------------------- bookkeeping
    def payload_structs(self, n_workers: int) -> list:
        """Abstract payload trees with the [n_workers, *stack] leading
        dims (what ``pack`` consumes) — for eval_shape checks/benches."""
        out = []
        for spec in self.specs:
            lead = (n_workers,) + spec.stack_shape
            out.append(jax.tree.map(
                lambda s, l=lead: jax.ShapeDtypeStruct(
                    l + tuple(s.shape), s.dtype),
                spec.treedef.unflatten(
                    [jax.ShapeDtypeStruct(c.shape,
                                          jnp.dtype(getattr(c, "dtype",
                                                            "int32")))
                     for c in spec.codecs])))
        return out

    def describe(self) -> list[dict]:
        """Static offset table (one row per leaf) for reports/tests."""
        return [{"offset": s.offset, "slice_nbytes": s.slice_nbytes,
                 "n_stack": s.n_stack, "codec": s.codec_id}
                for s in self.specs]


@dataclass(frozen=True)
class StagedWireLayout:
    """K contiguous stage sub-buffers repartitioning one ``WireLayout``
    along the staged wire pipeline (DESIGN.md §8).

    Each stage is itself a ``WireLayout`` over a subset of the plan's
    leaves (offsets rebased to be contiguous within the stage), so the
    per-stage pack/unpack reuses the exact §6 codec machinery — every
    leaf keeps its byte layout, only its *home buffer* changes. The
    stage byte counts sum to ``base.total_nbytes`` byte-for-byte: the
    "exactly ONE u8 all-gather of total_nbytes" invariant of §6 relaxes
    to "exactly K u8 all-gathers whose bytes sum to total_nbytes"."""
    base: WireLayout                          # the monolithic layout
    stage_leaf_ids: tuple[tuple[int, ...], ...]  # per stage, plan-leaf ids
    stages: tuple[WireLayout, ...]            # per-stage sub-layouts

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def total_nbytes(self) -> int:
        return self.base.total_nbytes

    @property
    def direction(self) -> str:
        return self.base.direction

    def stage_nbytes(self, k: int) -> int:
        return self.stages[k].total_nbytes

    def pack_stage(self, k: int, flat_payloads: list) -> jax.Array:
        """Pack stage ``k``'s leaves out of the FULL plan-flat payload
        list (same convention as ``WireLayout.pack``) into that stage's
        ``[n_workers, stage_nbytes(k)]`` uint8 sub-buffer."""
        return self.stages[k].pack(
            [flat_payloads[i] for i in self.stage_leaf_ids[k]])

    def unpack_stage(self, k: int, buf: jax.Array) -> list:
        """Bit-exact inverse of ``pack_stage``: payload list aligned with
        ``stage_leaf_ids[k]``."""
        return self.stages[k].unpack(buf)


def build_staged_layout(layout: WireLayout,
                        stage_leaf_ids) -> StagedWireLayout:
    """Repartition ``layout`` into per-stage sub-layouts. The stage leaf
    id lists must partition ``range(len(layout.specs))`` — every leaf in
    exactly one stage — so the repartition is byte-exact by
    construction (validated)."""
    stage_leaf_ids = tuple(tuple(ids) for ids in stage_leaf_ids)
    flat = [i for ids in stage_leaf_ids for i in ids]
    if sorted(flat) != list(range(len(layout.specs))):
        raise ValueError(
            f"stage leaf ids {stage_leaf_ids} do not partition the "
            f"{len(layout.specs)} layout leaves")
    stages = []
    for ids in stage_leaf_ids:
        specs, offset = [], 0
        for i in ids:
            spec = dataclasses.replace(layout.specs[i], offset=offset)
            offset += spec.region_nbytes
            specs.append(spec)
        stages.append(WireLayout(specs=tuple(specs), total_nbytes=offset,
                                 direction=layout.direction))
    assert sum(s.total_nbytes for s in stages) == layout.total_nbytes
    return StagedWireLayout(base=layout, stage_leaf_ids=stage_leaf_ids,
                            stages=tuple(stages))


def build_layout(plan: Any, wire_dtype, direction: str = "w2s") -> WireLayout:
    """The WireLayout for a LayerPlan and direction — the static offset
    table the fused payload all-gather (w2s) or model-update broadcast
    (s2w, §9) is laid out by. ``direction`` selects which resolved
    compressor family (``lp.w2s`` / ``lp.s2w``) defines each leaf's
    payload structure; the byte machinery is direction-agnostic."""
    if direction not in ("w2s", "s2w"):
        raise ValueError(f"direction must be 'w2s' or 's2w', got "
                         f"{direction!r}")
    specs = []
    offset = 0
    for lp in plan.leaves:
        comp = getattr(lp, direction)
        in_dtype = (jnp.float32 if getattr(comp, "lossless_wire", False)
                    else jnp.dtype(wire_dtype))
        struct = _payload_struct(comp, lp.slice_shape, in_dtype)
        codecs, treedef = leaf_codecs(comp, lp.slice_shape, struct)
        splits, pos = [], 0
        for c in codecs:
            splits.append(pos)
            pos += c.nbytes
        cid = getattr(comp, "name", type(comp).__name__) + "[" + \
            "+".join(c.cid for c in codecs) + "]"
        specs.append(WireSpec(
            offset=offset, slice_nbytes=pos, stack_shape=lp.stack_shape,
            n_stack=lp.n_stack, codec_id=cid, treedef=treedef,
            codecs=codecs, splits=tuple(splits)))
        offset += specs[-1].region_nbytes
    return WireLayout(specs=tuple(specs), total_nbytes=offset,
                      direction=direction)
