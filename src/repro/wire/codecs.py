"""Per-payload-leaf wire codecs (DESIGN.md §6).

A *codec* is a bit-exact ``pack(array) -> uint8[nbytes]`` /
``unpack(bytes) -> array`` pair for one fixed-shape payload leaf. Two
cover every compressor in the registry:

  RawCodec        any array, byte-for-byte (bitcast).  bf16 value blobs,
                  Natural uint8 code planes, the already-bit-packed
                  Natural sign bitmaps, f32 lossless (Identity) diffs.
  NarrowIntCodec  int32 index arrays whose domain fits 2 (uint16) or
                  3 (uint24) bytes — TopK/ColumnTopK indices.  Width 4
                  degrades gracefully to raw little-endian int32.

The 9-bit Natural wire format falls out of composition: the uint8
exponent-code plane (RawCodec, 8 bits/value) and the 1-bit-packed sign
bitmap (RawCodec over the ``kernels.bitpack``-packed plane, 1 bit/value)
are laid out back-to-back in the same buffer region by the WireLayout.

Codec selection (``leaf_codecs``) is static: it reads the resolved
compressor and the abstract payload structure, never array values, so a
``WireLayout`` is built once per LayerPlan and reused by every traced
step. On TPU the narrow codecs run the Pallas kernels in
``kernels/bitpack.py``; on CPU they use the bit-identical jnp references
(the interpret-mode fallback that keeps tests exact).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import compressors as C
from repro.core.compressors import _nelem
from repro.kernels.bitpack import narrow_decode, narrow_encode, narrow_width


def _to_bytes(x: jax.Array) -> jax.Array:
    """Flatten any fixed-shape array to its uint8 byte view."""
    if x.dtype == jnp.uint8:
        return x.reshape(-1)
    if x.dtype == jnp.bool_:
        return x.astype(jnp.uint8).reshape(-1)
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


def _from_bytes(b: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    """Inverse of ``_to_bytes`` (bit-exact)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint8:
        return b.reshape(shape)
    if dtype == jnp.bool_:
        return b.reshape(shape).astype(jnp.bool_)
    it = dtype.itemsize
    return jax.lax.bitcast_convert_type(
        b.reshape(tuple(shape) + (it,)), dtype)


@dataclass(frozen=True)
class RawCodec:
    """Byte-for-byte bitcast of one payload leaf."""
    shape: tuple[int, ...]
    dtype: str                      # dtype name (keeps the dataclass hashable)

    @property
    def nbytes(self) -> int:
        return _nelem(self.shape) * jnp.dtype(self.dtype).itemsize

    @property
    def cid(self) -> str:
        return f"raw:{self.dtype}"

    def pack(self, x: jax.Array) -> jax.Array:
        assert tuple(x.shape) == tuple(self.shape), (x.shape, self.shape)
        return _to_bytes(x)

    def unpack(self, b: jax.Array) -> jax.Array:
        return _from_bytes(b, self.shape, self.dtype)


@dataclass(frozen=True)
class NarrowIntCodec:
    """int32 indices in [0, 2^(8*width)) as width-byte planes."""
    shape: tuple[int, ...]
    width: int                      # 2 (uint16) or 3 (uint24); 4 = raw

    @property
    def nbytes(self) -> int:
        return _nelem(self.shape) * self.width

    @property
    def cid(self) -> str:
        return f"u{8 * self.width}"

    def pack(self, x: jax.Array) -> jax.Array:
        assert tuple(x.shape) == tuple(self.shape), (x.shape, self.shape)
        return narrow_encode(x.astype(jnp.int32).reshape(-1), self.width)

    def unpack(self, b: jax.Array) -> jax.Array:
        return narrow_decode(b, self.width).reshape(self.shape)


def index_domains(comp: Any, slice_shape: tuple[int, ...]) -> dict[str, int]:
    """Payload-leaf name -> index domain size, for leaves that hold
    positions rather than values (eligible for narrow encoding)."""
    inner = comp.inner if isinstance(comp, C.WithNatural) else comp
    if isinstance(inner, C.TopK):
        return {"indices": _nelem(slice_shape)}
    if isinstance(inner, C.ColumnTopK):
        return {"indices": int(slice_shape[-1])}
    return {}


def leaf_codecs(comp: Any, slice_shape: tuple[int, ...],
                payload_struct: Any) -> tuple[tuple, Any]:
    """(codecs, treedef) for one resolved compressor's per-slice payload.

    ``payload_struct`` is the abstract (ShapeDtypeStruct) payload of one
    slice; codecs are returned in payload-flatten order.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(payload_struct)
    domains = index_domains(comp, slice_shape)
    codecs = []
    for path, leaf in flat:
        name = getattr(path[-1], "key", "") if path else ""
        if name in domains and jnp.issubdtype(leaf.dtype, jnp.integer):
            width = narrow_width(domains[name])
            if width < 4:
                codecs.append(NarrowIntCodec(tuple(leaf.shape), width))
                continue
        codecs.append(RawCodec(tuple(leaf.shape), jnp.dtype(leaf.dtype).name))
    return tuple(codecs), treedef
