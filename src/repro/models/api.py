"""Model zoo API.

Every architecture family implements the same functional surface:

    model = build_model(cfg)                       # cfg: ArchConfig
    params, metas = model.init(key)                # metas drive layer-wise LMOs
    loss = model.loss(params, batch)               # scalar (train step objective)
    cache = model.init_cache(batch_size, max_len)  # decode state (KV / recurrent)
    logits, cache = model.prefill(params, batch, cache)
    logits, cache = model.decode_step(params, batch, cache)

Batches are dicts of arrays. ``input_specs`` builds the ShapeDtypeStruct
stand-ins for the dry-run (no allocation), including the modality-frontend
stubs for [vlm]/[audio] (precomputed patch/frame embeddings — the one
allowed carve-out).

Shape kinds:
  train   -> {"tokens"|"embeds"(+"pos")|"frames", "labels"} with a leading
             [n_workers, batch/n_workers] pair of dims.
  prefill -> same content, [batch] leading dim, no labels.
  decode  -> {"token": [B,1], "t": []} consumed together with a cache of
             length seq_len (the shape's seq is the *cache* length).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from .transformer import Transformer
        return Transformer(cfg)
    if cfg.family == "audio":
        from .whisper import WhisperModel
        return WhisperModel(cfg)
    if cfg.family == "ssm":
        from .xlstm import XLSTMModel
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        from .griffin import GriffinModel
        return GriffinModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def abstract_params(model) -> tuple:
    """(param ShapeDtypeStructs, metas) from ``model.init`` without
    allocating. ParamMeta is not a JAX type, so it is captured via
    closure; the one place this idiom lives (trainer, serving and the
    dry-run all call here)."""
    box = {}

    def initp(k):
        p, m = model.init(k)
        box["metas"] = m
        return p

    shapes = jax.eval_shape(initp, jax.random.key(0))
    return shapes, box["metas"]


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, n_workers: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape kind.

    For decode kinds the cache is part of the input; build it with
    ``build_model(cfg).cache_spec(shape.batch, shape.seq)``.
    """
    S, B = shape.seq, shape.batch
    i32 = jnp.int32
    if shape.kind == "train":
        assert B % n_workers == 0, (B, n_workers)
        lead = (n_workers, B // n_workers)
        sds = lambda *s, dt=i32: jax.ShapeDtypeStruct(lead + s, dt)
        if cfg.family == "vlm":
            return {"embeds": sds(S, cfg.d_model, dt=_dt(cfg)),
                    "pos": sds(S, 3), "labels": sds(S)}
        if cfg.family == "audio":
            enc = cfg.encoder
            return {"frames": sds(enc.n_frames, cfg.d_model, dt=_dt(cfg)),
                    "tokens": sds(S), "labels": sds(S)}
        return {"tokens": sds(S), "labels": sds(S)}
    if shape.kind == "prefill":
        sds = lambda *s, dt=i32: jax.ShapeDtypeStruct((B,) + s, dt)
        if cfg.family == "vlm":
            return {"embeds": sds(S, cfg.d_model, dt=_dt(cfg)), "pos": sds(S, 3)}
        if cfg.family == "audio":
            return {"frames": sds(cfg.encoder.n_frames, cfg.d_model, dt=_dt(cfg)),
                    "tokens": sds(S)}
        return {"tokens": sds(S)}
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), i32),
                "t": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(shape.kind)


def make_batch(cfg: ArchConfig, shape: ShapeSpec, key: jax.Array,
               n_workers: int = 1) -> dict:
    """Materialise a random batch matching ``input_specs`` (smoke tests)."""
    specs = input_specs(cfg, shape, n_workers)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab if name in ("tokens", "labels", "token") else max(
                shape.seq, 4)
            out[name] = jax.random.randint(sub, s.shape, 0, hi, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype) * 0.02
    if "t" in out:
        out["t"] = jnp.asarray(shape.seq - 1, jnp.int32)
    return out
