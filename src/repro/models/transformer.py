"""Unified decoder-only transformer covering the dense / MoE / VLM
assigned architectures:

  * GQA attention with RoPE / M-RoPE (Qwen2-VL 3-D sections), optional QKV
    bias, optional sliding window (ring-buffer decode cache).
  * SwiGLU / GELU MLPs, RMSNorm / LayerNorm.
  * Mixture-of-Experts with sort-based capacity dispatch (Mixtral softmax
    top-2; DeepSeek sigmoid top-8 + shared experts), switch-style
    load-balance auxiliary loss.
  * DeepSeek-V3 MLA: low-rank Q/KV projections, decoupled RoPE key, latent
    KV cache with *absorbed* decode (scores and values computed in the
    kv_lora latent space — the cache stores [B, S, kv_lora + rope] only).
  * Multi-token prediction (MTP) auxiliary head (DeepSeek-V3).
  * Token or precomputed-embedding inputs (VLM patch-embedding stub).

Layers are stacked ([L, ...] parameters) and executed with lax.scan;
training bodies are wrapped in jax.checkpoint (full remat) so 32k-token
activations never live across layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.muon import ParamMeta

from .common import (apply_rope, attention, chunked_softmax_xent,
                     decode_attention, embed_init, layer_norm, logits_last,
                     matrix_init, rms_norm, vector_init)


# ------------------------------------------------------------------ builders

class ParamBuilder:
    """Accumulates (params, metas) trees with identical structure."""

    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.dtype = dtype
        self.params: dict = {}
        self.metas: dict = {}

    def sub(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    def matrix(self, path: str, in_dim: int, out_dim: int,
               stack: tuple[int, ...] = (), scale: float | None = None):
        p, m = matrix_init(self.sub(), in_dim, out_dim, self.dtype,
                           stack=stack, scale=scale)
        self._set(path, p, m)

    def vector(self, path: str, dim: int, stack: tuple[int, ...] = (),
               value: float | None = None):
        p, m = vector_init(self.sub(), dim, self.dtype, stack=stack,
                           value=value)
        self._set(path, p, m)

    def embed(self, path: str, vocab: int, dim: int):
        p, m = embed_init(self.sub(), vocab, dim, self.dtype)
        self._set(path, p, m)

    def _set(self, path: str, p, m):
        parts = path.split("/")
        d_p, d_m = self.params, self.metas
        for k in parts[:-1]:
            d_p = d_p.setdefault(k, {})
            d_m = d_m.setdefault(k, {})
        d_p[parts[-1]] = p
        d_m[parts[-1]] = m


def _norm(cfg: ArchConfig, p: dict, prefix: str, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p[prefix + "_w"], p[prefix + "_b"], cfg.norm_eps)
    return rms_norm(x, p[prefix + "_w"], cfg.norm_eps)


def _add_norm_params(b: ParamBuilder, cfg: ArchConfig, path: str,
                     dim: int, stack=()):
    b.vector(path + "_w", dim, stack=stack, value=1.0)
    if cfg.norm == "layernorm":
        b.vector(path + "_b", dim, stack=stack, value=0.0)


def _act(cfg: ArchConfig, gate: jax.Array | None, up: jax.Array) -> jax.Array:
    if cfg.act in ("swiglu",):
        return jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    if cfg.act == "geglu":
        return jax.nn.gelu(gate.astype(jnp.float32)).astype(up.dtype) * up
    return jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype)


def _gated(cfg: ArchConfig) -> bool:
    return cfg.act in ("swiglu", "geglu")


def _add_mlp_params(b: ParamBuilder, cfg: ArchConfig, path: str, d: int,
                    ff: int, stack=()):
    if _gated(cfg):
        b.matrix(path + "/w_gate", d, ff, stack=stack)
    b.matrix(path + "/w_up", d, ff, stack=stack)
    b.matrix(path + "/w_down", ff, d, stack=stack,
             scale=1.0 / math.sqrt(ff))


def _mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    gate = x @ p["w_gate"] if _gated(cfg) else None
    return _act(cfg, gate, up) @ p["w_down"]


# ----------------------------------------------------------------- attention

def _add_attn_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack=()):
    d, hd = cfg.d_model, cfg.hd
    if cfg.mla is not None:
        mla = cfg.mla
        qk = mla.qk_nope + mla.qk_rope
        b.matrix(path + "/q_a", d, mla.q_lora, stack=stack)
        b.vector(path + "/q_norm_w", mla.q_lora, stack=stack, value=1.0)
        b.matrix(path + "/q_b", mla.q_lora, cfg.n_heads * qk, stack=stack)
        b.matrix(path + "/kv_a", d, mla.kv_lora + mla.qk_rope, stack=stack)
        b.vector(path + "/kv_norm_w", mla.kv_lora, stack=stack, value=1.0)
        b.matrix(path + "/kv_b", mla.kv_lora,
                 cfg.n_heads * (mla.qk_nope + mla.v_dim), stack=stack)
        b.matrix(path + "/wo", cfg.n_heads * mla.v_dim, d, stack=stack,
                 scale=1.0 / math.sqrt(cfg.n_heads * mla.v_dim))
        return
    b.matrix(path + "/wq", d, cfg.n_heads * hd, stack=stack)
    b.matrix(path + "/wk", d, cfg.n_kv_heads * hd, stack=stack)
    b.matrix(path + "/wv", d, cfg.n_kv_heads * hd, stack=stack)
    b.matrix(path + "/wo", cfg.n_heads * hd, d, stack=stack,
             scale=1.0 / math.sqrt(cfg.n_heads * hd))
    if cfg.qkv_bias:
        for n in ("bq", "bk", "bv"):
            dim = cfg.n_heads * hd if n == "bq" else cfg.n_kv_heads * hd
            b.vector(path + f"/{n}", dim, stack=stack, value=0.0)


def _rope(cfg: ArchConfig, x: jax.Array, pos: jax.Array) -> jax.Array:
    if cfg.rope in ("none", "learned"):
        return x
    sections = cfg.mrope_sections if cfg.rope == "mrope" else None
    return apply_rope(x, pos, base=cfg.rope_base, mrope_sections=sections)


def _gqa_attn(cfg: ArchConfig, p: dict, h: jax.Array, pos: jax.Array,
              cache: dict | None, t, mode: str, causal: bool = True):
    """Standard GQA attention. Returns (out, new_cache_entries)."""
    b_, s, _ = h.shape
    hd = cfg.hd
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b_, s, cfg.n_heads, hd)
    k = k.reshape(b_, s, cfg.n_kv_heads, hd)
    v = v.reshape(b_, s, cfg.n_kv_heads, hd)
    q = _rope(cfg, q, pos)
    k = _rope(cfg, k, pos)

    if mode in ("full", "prefill"):
        out = attention(q, k, v, causal=causal, window=cfg.window)
        new_cache = None
        if mode == "prefill":
            cap = cache["k"].shape[1]
            if cap >= s:
                kc = jnp.pad(k, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
            else:  # ring buffer: last `cap` tokens at slot (abs_pos % cap)
                idx = (jnp.arange(s - cap, s)) % cap
                kc = jnp.zeros_like(cache["k"]).at[:, idx].set(k[:, -cap:])
                vc = jnp.zeros_like(cache["v"]).at[:, idx].set(v[:, -cap:])
            new_cache = {"k": kc.astype(cache["k"].dtype),
                         "v": vc.astype(cache["v"].dtype)}
        return out, new_cache

    # decode: write the new kv at slot t (ring for windowed caches)
    cap = cache["k"].shape[1]
    slot = jnp.asarray(t, jnp.int32) % cap
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    kv_len = jnp.minimum(jnp.asarray(t, jnp.int32) + 1, cap)
    out = decode_attention(q, kc, vc, kv_len=kv_len)
    return out, {"k": kc, "v": vc}


def _mla_attn(cfg: ArchConfig, p: dict, h: jax.Array, pos: jax.Array,
              cache: dict | None, t, mode: str):
    """DeepSeek-V3 multi-head latent attention."""
    mla = cfg.mla
    b_, s, _ = h.shape
    H, nope, rope_d, vd = cfg.n_heads, mla.qk_nope, mla.qk_rope, mla.v_dim
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = rms_norm(h @ p["q_a"], p["q_norm_w"], cfg.norm_eps) @ p["q_b"]
    q = q.reshape(b_, s, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos, base=cfg.rope_base)

    kv_a = h @ p["kv_a"]
    c_kv = rms_norm(kv_a[..., :mla.kv_lora], p["kv_norm_w"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, mla.kv_lora:], pos,
                        base=cfg.rope_base)  # [B,S,1,rope]

    if mode in ("full", "prefill"):
        kv = (c_kv @ p["kv_b"]).reshape(b_, s, H, nope + vd)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b_, s, H, rope_d))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        # chunked attention wants matching k/v head dims: zero-pad v
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope_d - vd)))
        out = attention(qf, k, vpad, causal=True, softmax_scale=scale)
        out = out[..., :vd].reshape(b_, s, H * vd) @ p["wo"]
        new_cache = None
        if mode == "prefill":
            cap = cache["c_kv"].shape[1]
            ckv = jnp.pad(c_kv, ((0, 0), (0, cap - s), (0, 0)))
            krp = jnp.pad(k_rope[:, :, 0], ((0, 0), (0, cap - s), (0, 0)))
            new_cache = {"c_kv": ckv.astype(cache["c_kv"].dtype),
                         "k_rope": krp.astype(cache["k_rope"].dtype)}
        return out, new_cache

    # absorbed decode: scores and values in the kv_lora latent space.
    cap = cache["c_kv"].shape[1]
    slot = jnp.asarray(t, jnp.int32) % cap
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), slot, axis=1)
    krp = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype),
        slot, axis=1)
    kv_len = jnp.minimum(jnp.asarray(t, jnp.int32) + 1, cap)

    w_kv = p["kv_b"].reshape(mla.kv_lora, H, nope + vd)
    w_uk, w_uv = w_kv[..., :nope], w_kv[..., nope:]
    # absorb W_uk into the query: q_lat [B,1,H,kv_lora]; all cache-sized
    # einsums accumulate in f32 without materialising f32 cache copies
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk,
                       preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bshl,bkl->bhsk", q_lat.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshr,bkr->bhsk", q_rope, krp,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    mask = jnp.arange(cap)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhsk,bkl->bshl", w.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    o = jnp.einsum("bshl,lhv->bshv", o_lat.astype(h.dtype), w_uv,
                   preferred_element_type=jnp.float32)
    out = o.reshape(b_, s, H * vd).astype(h.dtype) @ p["wo"]
    return out, {"c_kv": ckv, "k_rope": krp}


# ----------------------------------------------------------------------- MoE

def _add_moe_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack=()):
    moe = cfg.moe
    d = cfg.d_model
    b.matrix(path + "/router", d, moe.n_experts, stack=stack)
    estack = stack + (moe.n_experts,)
    if _gated(cfg):
        b.matrix(path + "/e_gate", d, moe.d_expert, stack=estack)
    b.matrix(path + "/e_up", d, moe.d_expert, stack=estack)
    b.matrix(path + "/e_down", moe.d_expert, d, stack=estack,
             scale=1.0 / math.sqrt(moe.d_expert))
    if moe.n_shared:
        _add_mlp_params(b, cfg, path + "/shared", d,
                        moe.n_shared * moe.d_expert, stack=stack)


MOE_COMBINE_F32 = False   # pre-§Perf-A1 behaviour toggle (see _moe_ffn)


def moe_capacity(moe, n_tokens: int) -> int:
    c = int(math.ceil(moe.top_k * n_tokens * moe.capacity_factor
                      / moe.n_experts))
    return max(1, min(c, n_tokens))


def _moe_ffn(cfg: ArchConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sort-based capacity-dispatch MoE. x [B,S,D] -> (out, aux_loss)."""
    moe = cfg.moe
    b_, s, d = x.shape
    T, E, K = b_ * s, moe.n_experts, moe.top_k
    C = moe_capacity(moe, T)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if moe.n_shared:  # DeepSeek-style sigmoid gate
        probs = jax.nn.sigmoid(logits)
    else:             # Mixtral-style softmax gate
        probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, K)
    weights = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)

    # switch-style load-balance auxiliary loss
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)

    # sort-based dispatch: assignments [T*K] sorted by expert id
    a = top_idx.reshape(-1)
    w = weights.reshape(-1)
    order = jnp.argsort(a, stable=True)
    tok_s = (order // K).astype(jnp.int32)
    w_s = w[order]
    counts = jnp.zeros((E,), jnp.int32).at[a].add(1)
    starts = jnp.cumsum(counts) - counts
    grid = starts[:, None] + jnp.arange(C)[None, :]          # [E, C]
    valid = jnp.arange(C)[None, :] < counts[:, None]
    grid = jnp.clip(grid, 0, T * K - 1)
    tok_ec = tok_s[grid]                                     # [E, C]
    w_ec = jnp.where(valid, w_s[grid], 0.0)

    xin = xf[tok_ec]                                         # [E, C, D]
    up = jnp.einsum("ecd,edf->ecf", xin, p["e_up"])
    if _gated(cfg):
        gate = jnp.einsum("ecd,edf->ecf", xin, p["e_gate"])
        hmid = _act(cfg, gate, up)
    else:
        hmid = _act(cfg, None, up)
    out_ec = jnp.einsum("ecf,efd->ecd", hmid, p["e_down"])

    # §Perf iteration A1: the combine scatter crosses the expert-parallel
    # boundary (all-to-all at scale) — send it in the model dtype, not
    # f32, and weight before the move. Top-k partial sums in bf16 are
    # fine (<= 9 addends). MOE_COMBINE_F32 restores the pre-A1 behaviour
    # (used by the perf-iteration measurements).
    acc_dt = jnp.float32 if MOE_COMBINE_F32 else x.dtype
    contrib = (out_ec * w_ec[..., None].astype(out_ec.dtype)).astype(acc_dt)
    out = jnp.zeros((T, d), acc_dt).at[tok_ec.reshape(-1)].add(
        contrib.reshape(-1, d))
    out = out.astype(x.dtype)
    if moe.n_shared:
        out = out + _mlp(cfg, p["shared"], xf)
    return out.reshape(b_, s, d), aux


# -------------------------------------------------------------------- blocks

def _block(cfg: ArchConfig, p: dict, x: jax.Array, pos: jax.Array,
           cache: dict | None, t, mode: str, is_moe: bool):
    attn_fn = _mla_attn if cfg.mla is not None else _gqa_attn
    h = _norm(cfg, p, "ln1", x)
    a_out, new_cache = attn_fn(cfg, p["attn"], h, pos, cache, t, mode)
    if cfg.mla is None:
        b_, s = x.shape[:2]
        a_out = a_out.reshape(b_, s, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
    x = x + a_out
    h = _norm(cfg, p, "ln2", x)
    if is_moe:
        f_out, aux = _moe_ffn(cfg, p["moe"], h)
    else:
        f_out, aux = _mlp(cfg, p["mlp"], h), jnp.zeros((), jnp.float32)
    return x + f_out, new_cache, aux


# ---------------------------------------------------------------- the model

class Transformer:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        moe = cfg.moe
        self.n_dense = cfg.moe_start_layer if moe else cfg.n_layers
        self.n_moe = cfg.n_layers - self.n_dense if moe else 0

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array):
        cfg = self.cfg
        b = ParamBuilder(key, jnp.dtype(cfg.dtype))
        b.embed("embed", cfg.vocab, cfg.d_model)
        if cfg.rope == "learned":
            b.embed("pos_embed", cfg.max_position, cfg.d_model)
        if not cfg.tied_embeddings:
            b.matrix("unembed", cfg.d_model, cfg.vocab,
                     scale=1.0 / math.sqrt(cfg.d_model))
            # unembed trains with the sign LMO (Scion's embedding treatment)
            b.metas["unembed"] = ParamMeta("sign", 1.0, 0)
        _add_norm_params(b, cfg, "final_ln", cfg.d_model)

        def add_blocks(name: str, n: int, is_moe: bool, ff: int):
            if n == 0:
                return
            stack = (n,)
            _add_norm_params(b, cfg, f"{name}/ln1", cfg.d_model, stack)
            _add_norm_params(b, cfg, f"{name}/ln2", cfg.d_model, stack)
            _add_attn_params(b, cfg, f"{name}/attn", stack)
            if is_moe:
                _add_moe_params(b, cfg, f"{name}/moe", stack)
            else:
                _add_mlp_params(b, cfg, f"{name}/mlp", cfg.d_model, ff, stack)

        dense_ff = cfg.dense_ff if cfg.dense_ff else cfg.d_ff
        add_blocks("dense_blocks", self.n_dense, False, dense_ff)
        add_blocks("moe_blocks", self.n_moe, True, 0)
        if cfg.mtp:
            b.matrix("mtp/proj", 2 * cfg.d_model, cfg.d_model)
            _add_norm_params(b, cfg, "mtp/ln_h", cfg.d_model)
            _add_norm_params(b, cfg, "mtp/ln_e", cfg.d_model)
            add_blocks("mtp/block", 1, False, dense_ff)
        return b.params, b.metas

    # -------------------------------------------------------------- plumbing
    def _stacks(self, params: dict):
        out = []
        if self.n_dense:
            out.append(("dense_blocks", params["dense_blocks"], False))
        if self.n_moe:
            out.append(("moe_blocks", params["moe_blocks"], True))
        return out

    def _run(self, params: dict, x: jax.Array, pos: jax.Array,
             cache: dict | None, t, mode: str, remat: bool):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = {} if cache is not None else None
        for name, stack_p, is_moe in self._stacks(params):
            def body(carry, xs, is_moe=is_moe):
                x, aux = carry
                p, c = xs
                x, nc, a = _block(cfg, p, x, pos, c, t, mode, is_moe)
                return (x, aux + a), nc

            if remat and mode == "full":
                body = jax.checkpoint(body)
            c_stack = cache[name] if cache is not None else None
            (x, aux_total), nc = jax.lax.scan(
                body, (x, aux_total), (stack_p, c_stack))
            if new_cache is not None:
                new_cache[name] = nc
        x = _norm(cfg, params, "final_ln", x)
        return x, new_cache, aux_total

    def _embed_in(self, params: dict, batch: dict):
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
            pos = batch["pos"]
        else:
            x = params["embed"][batch["tokens"] if "tokens" in batch
                                else batch["token"]]
            s = x.shape[1]
            pos = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
            if cfg.rope == "mrope":
                pos = jnp.broadcast_to(pos[..., None], pos.shape + (3,))
        if cfg.rope == "learned":
            x = x + params["pos_embed"][
                jnp.clip(pos, 0, cfg.max_position - 1)]
        return x, pos

    def _unembed(self, params: dict):
        if self.cfg.tied_embeddings:
            return params["embed"].T
        return params["unembed"]

    # ------------------------------------------------------------------ loss
    def loss(self, params: dict, batch: dict, *, remat: bool = True):
        cfg = self.cfg
        x, pos = self._embed_in(params, batch)
        h, _, aux = self._run(params, x, pos, None, None, "full", remat)
        un = self._unembed(params)
        out = chunked_softmax_xent(h, un, batch["labels"])
        if cfg.moe:
            out = out + 0.01 * aux / max(self.n_moe, 1)
        if cfg.mtp and "tokens" in batch:
            out = out + 0.3 * self._mtp_loss(params, h, batch)
        return out

    def _mtp_loss(self, params: dict, h: jax.Array, batch: dict):
        """DeepSeek-V3 MTP: one extra block predicts token t+2 from
        (h_t, embed(token_{t+1}))."""
        cfg = self.cfg
        p = params["mtp"]
        tok_next = batch["tokens"][:, 1:]
        e = params["embed"][tok_next]
        comb = jnp.concatenate(
            [_norm(cfg, p, "ln_h", h[:, :-1]),
             _norm(cfg, p, "ln_e", e)], axis=-1) @ p["proj"]
        s = comb.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], comb.shape[:2])
        blk = jax.tree.map(lambda a: a[0], p["block"])
        hm, _, _ = _block(cfg, blk, comb, pos, None, None, "full", False)
        labels_mtp = batch["labels"][:, 1:]
        mask = jnp.ones_like(labels_mtp, dtype=bool).at[:, -1].set(False)
        return chunked_softmax_xent(hm, self._unembed(params), labels_mtp,
                                    mask=mask)

    # ----------------------------------------------------------------- cache
    def _cache_entry(self, batch_size: int, cap: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.mla is not None:
            return {"c_kv": ((batch_size, cap, cfg.mla.kv_lora), dt),
                    "k_rope": ((batch_size, cap, cfg.mla.qk_rope), dt)}
        return {"k": ((batch_size, cap, cfg.n_kv_heads, cfg.hd), dt),
                "v": ((batch_size, cap, cfg.n_kv_heads, cfg.hd), dt)}

    def _cache_tree(self, batch_size: int, max_len: int, make):
        cfg = self.cfg
        cap = min(cfg.window, max_len) if cfg.window else max_len
        entry = self._cache_entry(batch_size, cap)
        out = {}
        for name, _, _ in self._stacks({"dense_blocks": 0, "moe_blocks": 0}):
            n = self.n_dense if name == "dense_blocks" else self.n_moe
            out[name] = {k: make((n,) + shape, dt)
                         for k, (shape, dt) in entry.items()}
        return out

    def cache_spec(self, batch_size: int, max_len: int):
        return self._cache_tree(batch_size, max_len, jax.ShapeDtypeStruct)

    def init_cache(self, batch_size: int, max_len: int):
        return self._cache_tree(batch_size, max_len, jnp.zeros)

    # --------------------------------------------------------------- serving
    def prefill(self, params: dict, batch: dict, cache: dict):
        x, pos = self._embed_in(params, batch)
        h, cache, _ = self._run(params, x, pos, cache, None, "prefill", False)
        return logits_last(h[:, -1], self._unembed(params)), cache

    def decode_step(self, params: dict, batch: dict, cache: dict):
        cfg = self.cfg
        t = batch["t"]
        x = params["embed"][batch["token"]]
        pos = jnp.broadcast_to(t[None, None], x.shape[:2]).astype(jnp.int32)
        if cfg.rope == "learned":
            x = x + params["pos_embed"][
                jnp.clip(pos, 0, cfg.max_position - 1)]
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[..., None], pos.shape + (3,))
        h, cache, _ = self._run(params, x, pos, cache, t, "decode", False)
        return logits_last(h[:, -1], self._unembed(params)), cache
