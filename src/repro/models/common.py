"""Shared model primitives: inits + metas, norms, RoPE/M-RoPE, chunked
flash-style attention (GQA / sliding-window / decode), chunked softmax
cross-entropy.

Conventions:
  * weights are [in, out]; activations are x @ W.
  * every init returns (params, metas) pairs with matching tree structure;
    ParamMeta drives the layer-wise LMO norm map (hidden matrices ->
    spectral, embeddings & vectors -> sign) per Scion/Gluon practice.
  * attention is computed with double chunking (query-chunk outer scan,
    kv-chunk inner scan, online softmax in f32) so 32k prefill fits without
    materialising S x S scores.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.lmo import default_radius_scale
from repro.core.muon import ParamMeta

# --------------------------------------------------------------------- inits

def matrix_init(key, in_dim: int, out_dim: int, dtype,
                stack: tuple[int, ...] = (), scale: float | None = None):
    """Gaussian fan-in init for a (possibly stacked) weight matrix, with the
    spectral-LMO meta."""
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, stack + (in_dim, out_dim), dtype) * scale
    meta = ParamMeta("spectral",
                     default_radius_scale((in_dim, out_dim), "spectral"),
                     stack_dims=len(stack))
    return w, meta


def vector_init(key, dim: int, dtype, stack: tuple[int, ...] = (),
                value: float | None = None):
    if value is not None:
        v = jnp.full(stack + (dim,), value, dtype)
    else:
        v = jax.random.normal(key, stack + (dim,), dtype) * 0.02
    return v, ParamMeta("sign", 1.0, stack_dims=len(stack),
                        compressible=False)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.normal(key, (vocab, dim), dtype) * 0.02
    return w, ParamMeta("sign", 1.0, stack_dims=0)


# --------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, base: float = 10000.0) -> jax.Array:
    return base ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                    / (head_dim // 2))


def apply_rope(x: jax.Array, pos: jax.Array, base: float = 10000.0,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """Rotary embedding.

    x:   [B, S, H, D]
    pos: [B, S] (standard) or [B, S, 3] (M-RoPE: temporal/height/width; the
         half-dim is split into `mrope_sections` channels per Qwen2-VL).
    """
    d2 = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], base)  # [d2]
    if mrope_sections is None:
        angle = pos.astype(jnp.float32)[..., None] * freqs  # [B,S,d2]
    else:
        assert sum(mrope_sections) == d2, (mrope_sections, d2)
        parts = []
        start = 0
        for ch, sec in enumerate(mrope_sections):
            p = pos[..., ch].astype(jnp.float32)  # [B,S]
            parts.append(p[..., None] * freqs[start:start + sec])
            start += sec
        angle = jnp.concatenate(parts, axis=-1)  # [B,S,d2]
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = x[..., :d2].astype(jnp.float32), x[..., d2:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q [B,Cq,KVH,G,D] x k [B,Ckv,KVH,D] -> [B,KVH,G,Cq,Ckv] (f32).

    f32 accumulation via preferred_element_type — no materialised f32
    copies of the operands (matters for HBM traffic at 32k contexts)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              q_offset: Any = 0, kv_len: Any = None,
              chunk_q: int = 1024, chunk_kv: int = 1024,
              softmax_scale: float | None = None) -> jax.Array:
    """Double-chunked online-softmax attention with GQA.

    q [B,Sq,Hq,D]; k, v [B,Skv,KVH,D] with Hq = KVH * G.
    ``q_offset``: absolute position of q[0] (decode / prefill continuation).
    ``kv_len``: number of valid kv positions (decode against a padded cache).
    ``window``: sliding-window size (attend to positions > pos - window).
    """
    b, sq, hq, d = q.shape
    _, skv, kvh, _ = k.shape
    g = hq // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    q = q.reshape(b, sq, kvh, g, d)

    chunk_q = min(chunk_q, sq)
    chunk_kv = min(chunk_kv, skv)
    # pad to chunk multiples
    pq = (-sq) % chunk_q
    pkv = (-skv) % chunk_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq = (sq + pq) // chunk_q
    nkv = (skv + pkv) // chunk_kv
    if kv_len is None:
        kv_len = skv
    kv_len = jnp.asarray(kv_len, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    kc = k.reshape(b, nkv, chunk_kv, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, chunk_kv, kvh, d).transpose(1, 0, 2, 3, 4)
    qc = q.reshape(b, nq, chunk_q, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)

    def q_step(_, qi_q):
        qi, qblk = qi_q  # qblk [B,Cq,KVH,G,D]
        qpos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            kpos = ki * chunk_kv + jnp.arange(chunk_kv)
            s = _gqa_scores(qblk, kblk) * scale  # [B,KVH,G,Cq,Ckv]
            mask = kpos[None, :] < kv_len
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, g, chunk_q), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, chunk_q), jnp.float32),
                jnp.zeros((b, kvh, g, chunk_q, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nkv), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KVH,G,Cq,D]
        return None, out.transpose(0, 3, 1, 2, 4)  # [B,Cq,KVH,G,D]

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pq, hq, d)
    return out[:, :sq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, *, kv_len, window: int | None = None,
                     softmax_scale: float | None = None):
    """Single-query attention against a (padded) cache.

    q [B,1,Hq,D]; caches [B,Smax,KVH,D]; kv_len scalar/array = valid length.
    For sliding windows the cache is a ring buffer of size `window`
    (positions are implicit; masking by validity only).
    """
    b, _, hq, d = q.shape
    _, smax, kvh, _ = k_cache.shape
    g = hq // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(smax)
    mask = idx[None, :] < jnp.asarray(kv_len, jnp.int32)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(v_cache.dtype)


# -------------------------------------------------------------- loss helpers

def chunked_softmax_xent(hidden: jax.Array, unembed: jax.Array,
                         labels: jax.Array, mask: jax.Array | None = None,
                         chunk: int = 1024) -> jax.Array:
    """Mean next-token cross-entropy with sequence-chunked logits so the
    [tokens, vocab] matrix never fully materialises.

    hidden [B,S,D], unembed [D,V], labels [B,S] (already shifted).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((b, s), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), bool)
    n = (s + pad) // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        h, lbl, msk = xs
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            unembed.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * msk
        return (tot + jnp.sum(nll), cnt + jnp.sum(msk)), None

    (tot, cnt), _ = jax.lax.scan(step, (0.0, 0.0), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def logits_last(hidden_last: jax.Array, unembed: jax.Array) -> jax.Array:
    """[B,D] x [D,V] -> [B,V] f32 logits (decode head)."""
    return jnp.einsum("bd,dv->bv", hidden_last.astype(jnp.float32),
                      unembed.astype(jnp.float32))
