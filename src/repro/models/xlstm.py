"""xLSTM (Beck et al., arXiv:2405.04517): mLSTM + sLSTM blocks.

* mLSTM — matrix-memory LSTM with exponential gating. Training/prefill use
  the *chunkwise-parallel* form (intra-chunk attention-like computation +
  inter-chunk recurrent state, fully log-space stabilised); decode is the
  exact sequential cell. The two are tested for equality
  (tests/test_xlstm.py).
* sLSTM — scalar-memory LSTM with exponential gating and block-diagonal
  per-head recurrent weights; inherently sequential (lax.scan over time).

Block layout follows the paper: pre-norm residual blocks; the mLSTM block
up-projects by 2x (the FFN role — the assigned config has d_ff = 0), the
sLSTM block is followed by a GeGLU up/down projection of factor 4/3.

Pattern handling: xLSTM[7:1] means each period is 7 mLSTM blocks + 1
sLSTM block; parameters are stacked [n_periods, slots_per_period, ...] and
executed with an outer lax.scan over periods.

Decode state per mLSTM layer: (c [B,H,hd,hd], n [B,H,hd], m [B,H]) — O(1)
in sequence length, which is what makes long_500k admissible.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .common import chunked_softmax_xent, logits_last, rms_norm
from .transformer import ParamBuilder, _add_norm_params, _norm

# §Perf iteration B1: 64 -> 256. The carried matrix memory C [B,H,hd,hd]
# (hd = 1024!) is read+written once per chunk; its traffic scales with
# S/chunk while the intra-chunk D/score tensors scale with S*chunk — at
# hd=1024 the state dominates, so bigger chunks win (measured 8.6 s ->
# see EXPERIMENTS.md §Perf).
CHUNK = 256


# ------------------------------------------------------------------- mLSTM

def _mlstm_dims(cfg: ArchConfig) -> tuple[int, int]:
    d_inner = 2 * cfg.d_model
    return d_inner, d_inner // cfg.n_heads


def _add_mlstm_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack):
    d = cfg.d_model
    d_inner, _ = _mlstm_dims(cfg)
    _add_norm_params(b, cfg, path + "/ln", d, stack)
    b.matrix(path + "/w_up", d, 2 * d_inner, stack=stack)
    for n in ("wq", "wk", "wv"):
        b.matrix(path + f"/{n}", d_inner, d_inner, stack=stack)
    b.matrix(path + "/w_if", d_inner, 2 * cfg.n_heads, stack=stack)
    b.vector(path + "/b_i", cfg.n_heads, stack=stack, value=0.0)
    # forget bias init ~ +3..6 keeps early training stable (paper App. B)
    b.vector(path + "/b_f", cfg.n_heads, stack=stack, value=4.0)
    b.vector(path + "/ln_out_w", d_inner, stack=stack, value=1.0)
    b.matrix(path + "/w_down", d_inner, d, stack=stack,
             scale=1.0 / math.sqrt(d_inner))


def _mlstm_gates(cfg: ArchConfig, p: dict, xm: jax.Array):
    """(log_i, log_f) pre-activations [B, S, H] in f32."""
    gif = (xm @ p["w_if"]).astype(jnp.float32)
    h = cfg.n_heads
    log_i = gif[..., :h] + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gif[..., h:] + p["b_f"].astype(jnp.float32))
    return log_i, log_f


def _mlstm_qkv(cfg: ArchConfig, p: dict, xm: jax.Array):
    b_, s, _ = xm.shape
    d_inner, hd = _mlstm_dims(cfg)
    shp = (b_, s, cfg.n_heads, hd)
    q = (xm @ p["wq"]).reshape(shp)
    k = (xm @ p["wk"]).reshape(shp) / math.sqrt(hd)
    v = (xm @ p["wv"]).reshape(shp)
    return q, k, v


def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = CHUNK):
    """Chunkwise-parallel stabilised mLSTM.

    q,k,v [B,S,H,hd]; log_i/log_f [B,S,H]. state = (c [B,H,hd,hd],
    n [B,H,hd], m [B,H]) or None. Returns (h [B,S,H,hd], state').

    The state is stored stabilised: true_C = c * exp(m)[...,None,None].
    """
    b_, s, H, hd = q.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    # [nc, B, H, c, ...] — §Perf iteration B2: q/k/v stay in the model
    # dtype (bf16 at full scale); only gates/stabilisers and accumulators
    # are f32. Halves the dot operand traffic and, crucially, the TP
    # backward all-reduces of the activation grads.
    qc = q.reshape(b_, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b_, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b_, nc, chunk, H, hd).transpose(1, 0, 3, 2, 4)
    ic = log_i.reshape(b_, nc, chunk, H).transpose(1, 0, 3, 2)
    fc = log_f.reshape(b_, nc, chunk, H).transpose(1, 0, 3, 2)

    if state is None:
        c0 = jnp.zeros((b_, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((b_, H, hd), jnp.float32)
        m0 = jnp.full((b_, H), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (a.astype(jnp.float32) for a in state)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        c_st, n_st, m_st = carry
        qb, kb, vb, ib, fb = xs           # [B,H,c,(hd)], [B,H,c]
        F = jnp.cumsum(fb, axis=-1)       # inclusive cumulative log-forget
        # log weight of key j for query i (j <= i): F_i - F_j + i_j
        lw = F[..., :, None] - F[..., None, :] + ib[..., None, :]
        lw = jnp.where(tri[None, None], lw, -1e30)
        # inter-chunk term for query i: F_i + m_state
        b_inter = F + m_st[..., None]                       # [B,H,c]
        m_loc = jnp.maximum(jnp.max(lw, axis=-1), b_inter)  # [B,H,c]
        m_loc = jnp.maximum(m_loc, -1e30)
        D = jnp.exp(lw - m_loc[..., None])                  # [B,H,c,c] f32
        raw = jnp.einsum("bhid,bhjd->bhij", qb, kb,
                         preferred_element_type=jnp.float32)
        scores = raw * D
        inter_scale = jnp.exp(b_inter - m_loc)              # [B,H,c]
        # §Perf B3: q never upcasts — states downcast at use so dq (and
        # its TP backward all-reduce) stays in the model dtype
        num = (jnp.einsum("bhij,bhjd->bhid", scores.astype(vb.dtype), vb,
                          preferred_element_type=jnp.float32)
               + inter_scale[..., None]
               * jnp.einsum("bhid,bhde->bhie", qb, c_st.astype(qb.dtype),
                            preferred_element_type=jnp.float32))
        # normaliser n_i = sum_j D_ij k_j + inter_scale_i * n_state
        n_vec = (jnp.einsum("bhij,bhjd->bhid", D.astype(kb.dtype), kb,
                            preferred_element_type=jnp.float32)
                 + inter_scale[..., None] * n_st[:, :, None, :])
        qn = jnp.abs(jnp.einsum("bhid,bhid->bhi", qb,
                                n_vec.astype(qb.dtype),
                                preferred_element_type=jnp.float32))
        den = jnp.maximum(qn, jnp.exp(-m_loc))
        h = num / den[..., None]
        # state update to end of chunk
        F_tot = F[..., -1]                                  # [B,H]
        dk = F_tot[..., None] - F + ib                      # [B,H,c]
        m_new = jnp.maximum(F_tot + m_st, jnp.max(dk, axis=-1))
        sc = jnp.exp(dk - m_new[..., None])
        c_new = (jnp.exp(F_tot + m_st - m_new)[..., None, None] * c_st
                 + jnp.einsum("bhj,bhjd,bhje->bhde",
                              sc.astype(kb.dtype), kb, vb,
                              preferred_element_type=jnp.float32))
        n_new = (jnp.exp(F_tot + m_st - m_new)[..., None] * n_st
                 + jnp.einsum("bhj,bhjd->bhd", sc.astype(kb.dtype), kb,
                              preferred_element_type=jnp.float32))
        return (c_new, n_new, m_new), h

    (c_st, n_st, m_st), hs = jax.lax.scan(body, (c0, n0, m0),
                                          (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b_, nc * chunk, H, hd)[:, :s]
    return h.astype(v.dtype), (c_st, n_st, m_st)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Exact sequential mLSTM cell (decode; also the chunkwise oracle).

    q,k,v [B,H,hd]; log_i/log_f [B,H]; state as in mlstm_chunkwise.
    """
    out_dtype = v.dtype
    c_st, n_st, m_st = (a.astype(jnp.float32) for a in state)
    q, k, v = (a.astype(jnp.float32) for a in (q, k, v))
    m_new = jnp.maximum(log_f + m_st, log_i)
    f_sc = jnp.exp(log_f + m_st - m_new)
    i_sc = jnp.exp(log_i - m_new)
    c_new = (f_sc[..., None, None] * c_st
             + i_sc[..., None, None] * k[..., :, None] * v[..., None, :])
    n_new = f_sc[..., None] * n_st + i_sc[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return h.astype(out_dtype), (c_new, n_new, m_new)


def _mlstm_block(cfg: ArchConfig, p: dict, x: jax.Array, cache, mode: str):
    d_inner, hd = _mlstm_dims(cfg)
    h = _norm(cfg, p, "ln", x)
    up = h @ p["w_up"]
    xm, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v = _mlstm_qkv(cfg, p, xm)
    log_i, log_f = _mlstm_gates(cfg, p, xm)
    if mode == "decode":
        state = (cache["c"], cache["n"], cache["m"])
        hq, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                               log_i[:, 0], log_f[:, 0], state)
        hv = hq[:, None]
        new_cache = {"c": state[0], "n": state[1], "m": state[2]}
    else:
        state = None if mode == "full" else (
            (cache["c"], cache["n"], cache["m"]) if cache else None)
        hv, state = mlstm_chunkwise(q, k, v, log_i, log_f, state=None)
        new_cache = ({"c": state[0], "n": state[1], "m": state[2]}
                     if mode == "prefill" else None)
    b_, s = x.shape[:2]
    hv = hv.reshape(b_, s, d_inner)
    hv = rms_norm(hv, p["ln_out_w"], cfg.norm_eps)
    out = (hv * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype)) @ p["w_down"]
    return x + out, new_cache


# ------------------------------------------------------------------- sLSTM

def _slstm_ff(cfg: ArchConfig) -> int:
    return ((4 * cfg.d_model // 3 + 63) // 64) * 64


def _add_slstm_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack):
    d = cfg.d_model
    hd = d // cfg.n_heads
    _add_norm_params(b, cfg, path + "/ln", d, stack)
    b.matrix(path + "/w_gates", d, 4 * d, stack=stack)  # z, i, f, o
    # block-diagonal recurrent weights per head and gate: [4, H, hd, hd]
    b.matrix(path + "/r_gates", hd, hd, stack=stack + (4, cfg.n_heads))
    b.vector(path + "/b_i", d, stack=stack, value=0.0)
    b.vector(path + "/b_f", d, stack=stack, value=4.0)
    b.vector(path + "/ln_out_w", d, stack=stack, value=1.0)
    ff = _slstm_ff(cfg)
    b.matrix(path + "/w_up_gate", d, ff, stack=stack)
    b.matrix(path + "/w_up", d, ff, stack=stack)
    b.matrix(path + "/w_down", ff, d, stack=stack,
             scale=1.0 / math.sqrt(ff))


def slstm_step(cfg: ArchConfig, p: dict, gates_x, state):
    """One sLSTM timestep. gates_x [B, 4, D] (input contributions);
    state = (c, n, h, m) each [B, D]."""
    H = cfg.n_heads
    d = cfg.d_model
    hd = d // H
    c, n, h, m = (a.astype(jnp.float32) for a in state)
    hh = h.reshape(-1, H, hd)
    rec = jnp.einsum("bhd,ghde->gbhe", hh,
                     p["r_gates"].astype(jnp.float32))  # [4,B,H,hd]
    rec = rec.reshape(4, -1, d)
    z_pre, i_pre, f_pre, o_pre = (gates_x.astype(jnp.float32)
                                  .transpose(1, 0, 2) + rec)
    i_pre = i_pre + p["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre + p["b_f"].astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f_sc * c + i_sc * z
    n_new = jnp.maximum(f_sc * n + i_sc, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def _slstm_block(cfg: ArchConfig, p: dict, x: jax.Array, cache, mode: str):
    b_, s, d = x.shape
    hin = _norm(cfg, p, "ln", x)
    gates_x = (hin @ p["w_gates"]).reshape(b_, s, 4, d)
    if mode == "decode":
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        state = slstm_step(cfg, p, gates_x[:, 0], state)
        hs = state[2][:, None].astype(x.dtype)
        new_cache = dict(zip(("c", "n", "h", "m"), state))
    else:
        z0 = jnp.zeros((b_, d), jnp.float32)
        init = (z0, z0 + 1e-6, z0, z0 - 1e30)

        def body(st, gx):
            st = slstm_step(cfg, p, gx, st)
            return st, st[2]

        state, hs = jax.lax.scan(body, init, gates_x.transpose(1, 0, 2, 3))
        hs = hs.transpose(1, 0, 2).astype(x.dtype)
        new_cache = (dict(zip(("c", "n", "h", "m"), state))
                     if mode == "prefill" else None)
    hs = rms_norm(hs, p["ln_out_w"], cfg.norm_eps)
    mid = (jax.nn.gelu((hs @ p["w_up_gate"]).astype(jnp.float32))
           .astype(x.dtype) * (hs @ p["w_up"]))
    return x + mid @ p["w_down"], new_cache


# ------------------------------------------------------------------- model

class XLSTMModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        pat = cfg.block_pattern
        assert cfg.n_layers % len(pat) == 0, (cfg.n_layers, pat)
        self.n_periods = cfg.n_layers // len(pat)
        self.n_m = sum(1 for k in pat if k == "m")
        self.n_s = sum(1 for k in pat if k == "s")

    def init(self, key):
        cfg = self.cfg
        b = ParamBuilder(key, jnp.dtype(cfg.dtype))
        b.embed("embed", cfg.vocab, cfg.d_model)
        b.matrix("unembed", cfg.d_model, cfg.vocab,
                 scale=1.0 / math.sqrt(cfg.d_model))
        from repro.core.muon import ParamMeta
        b.metas["unembed"] = ParamMeta("sign", 1.0, 0)
        _add_norm_params(b, cfg, "final_ln", cfg.d_model)
        stack = (self.n_periods,)
        _add_mlstm_params(b, cfg, "m_blocks", stack + (self.n_m,))
        _add_slstm_params(b, cfg, "s_blocks", stack + (self.n_s,))
        return b.params, b.metas

    def _run(self, params, x, cache, mode: str, remat: bool):
        cfg = self.cfg
        pat = cfg.block_pattern

        def period(carry, xs):
            x = carry
            pm, ps, cm, cs = xs
            im = is_ = 0
            ncm, ncs = [], []
            for kind in pat:
                if kind == "m":
                    p = jax.tree.map(lambda a: a[im], pm)
                    c = jax.tree.map(lambda a: a[im], cm) if cm else None
                    x, nc = _mlstm_block(cfg, p, x, c, mode)
                    ncm.append(nc)
                    im += 1
                else:
                    p = jax.tree.map(lambda a: a[is_], ps)
                    c = jax.tree.map(lambda a: a[is_], cs) if cs else None
                    x, nc = _slstm_block(cfg, p, x, c, mode)
                    ncs.append(nc)
                    is_ += 1
            stk = lambda lst: (jax.tree.map(lambda *a: jnp.stack(a), *lst)
                               if lst and lst[0] is not None else None)
            return x, (stk(ncm), stk(ncs))

        if remat and mode == "full":
            period = jax.checkpoint(period)
        cm = cache["m_blocks"] if cache else None
        cs = cache["s_blocks"] if cache else None
        x, (ncm, ncs) = jax.lax.scan(
            period, x, (params["m_blocks"], params["s_blocks"], cm, cs))
        new_cache = ({"m_blocks": ncm, "s_blocks": ncs}
                     if mode in ("prefill", "decode") else None)
        return _norm(cfg, params, "final_ln", x), new_cache

    def loss(self, params, batch, *, remat: bool = True):
        x = params["embed"][batch["tokens"]]
        h, _ = self._run(params, x, None, "full", remat)
        return chunked_softmax_xent(h, params["unembed"], batch["labels"])

    # ----------------------------------------------------------------- cache
    def _cache_tree(self, batch_size: int, max_len: int, make):
        cfg = self.cfg
        d_inner, hd = _mlstm_dims(cfg)
        H, d = cfg.n_heads, cfg.d_model
        f32 = jnp.float32
        P = self.n_periods
        m_entry = {"c": ((P, self.n_m, batch_size, H, hd, hd), f32),
                   "n": ((P, self.n_m, batch_size, H, hd), f32),
                   "m": ((P, self.n_m, batch_size, H), f32)}
        s_entry = {k: ((P, self.n_s, batch_size, d), f32)
                   for k in ("c", "n", "h", "m")}
        return {"m_blocks": {k: make(s, dt) for k, (s, dt) in m_entry.items()},
                "s_blocks": {k: make(s, dt) for k, (s, dt) in s_entry.items()}}

    def cache_spec(self, batch_size: int, max_len: int):
        return self._cache_tree(batch_size, max_len, jax.ShapeDtypeStruct)

    def init_cache(self, batch_size: int, max_len: int):
        return self._cache_tree(batch_size, max_len, jnp.zeros)

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch, cache):
        x = params["embed"][batch["tokens"]]
        h, cache = self._run(params, x, cache, "prefill", False)
        return logits_last(h[:, -1], params["unembed"]), cache

    def decode_step(self, params, batch, cache):
        x = params["embed"][batch["token"]]
        h, cache = self._run(params, x, cache, "decode", False)
        return logits_last(h[:, -1], params["unembed"]), cache
