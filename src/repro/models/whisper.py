"""Whisper (Radford et al., arXiv:2212.04356) — encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is a STUB (the one allowed
carve-out): the encoder consumes precomputed frame embeddings
[B, n_frames, d_model] provided by ``input_specs``/the data pipeline.

* Encoder: bidirectional MHA blocks over frames, fixed sinusoidal
  positions, LayerNorm + GELU (pre-norm), final LayerNorm.
* Decoder: causal self-attention + cross-attention to the encoder output
  + GELU MLP; learned positional embeddings.
* Serving: prefill encodes the frames once and precomputes per-layer
  cross-attention K/V (cached); decode runs single-token self-attention
  against a [seq_len] cache + cross-attention against the frame K/V.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .common import (attention, chunked_softmax_xent, decode_attention,
                     logits_last)
from .transformer import (ParamBuilder, _add_attn_params, _add_mlp_params,
                          _add_norm_params, _gqa_attn, _mlp, _norm)


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's fixed sinusoidal position embedding."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2,
                                              dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _add_cross_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack):
    d, hd = cfg.d_model, cfg.hd
    b.matrix(path + "/wq", d, cfg.n_heads * hd, stack=stack)
    b.matrix(path + "/wk", d, cfg.n_kv_heads * hd, stack=stack)
    b.matrix(path + "/wv", d, cfg.n_kv_heads * hd, stack=stack)
    b.matrix(path + "/wo", cfg.n_heads * hd, d, stack=stack,
             scale=1.0 / math.sqrt(cfg.n_heads * hd))


class WhisperModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.encoder is not None

    def init(self, key):
        cfg = self.cfg
        b = ParamBuilder(key, jnp.dtype(cfg.dtype))
        b.embed("embed", cfg.vocab, cfg.d_model)
        b.embed("pos_embed", cfg.max_position, cfg.d_model)
        from repro.core.muon import ParamMeta
        b.matrix("unembed", cfg.d_model, cfg.vocab,
                 scale=1.0 / math.sqrt(cfg.d_model))
        b.metas["unembed"] = ParamMeta("sign", 1.0, 0)

        enc_stack = (cfg.encoder.n_layers,)
        _add_norm_params(b, cfg, "enc_blocks/ln1", cfg.d_model, enc_stack)
        _add_norm_params(b, cfg, "enc_blocks/ln2", cfg.d_model, enc_stack)
        _add_attn_params(b, cfg, "enc_blocks/attn", enc_stack)
        _add_mlp_params(b, cfg, "enc_blocks/mlp", cfg.d_model, cfg.d_ff,
                        enc_stack)
        _add_norm_params(b, cfg, "enc_final_ln", cfg.d_model)

        dec_stack = (cfg.n_layers,)
        for ln in ("ln1", "ln_x", "ln2"):
            _add_norm_params(b, cfg, f"dec_blocks/{ln}", cfg.d_model,
                             dec_stack)
        _add_attn_params(b, cfg, "dec_blocks/attn", dec_stack)
        _add_cross_params(b, cfg, "dec_blocks/xattn", dec_stack)
        _add_mlp_params(b, cfg, "dec_blocks/mlp", cfg.d_model, cfg.d_ff,
                        dec_stack)
        _add_norm_params(b, cfg, "final_ln", cfg.d_model)
        return b.params, b.metas

    # ---------------------------------------------------------------- encode
    def encode(self, params, frames: jax.Array, *, remat: bool = False):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def body(x, p):
            h = _norm(cfg, p, "ln1", x)
            a, _ = _gqa_attn(cfg, p["attn"], h, pos, None, None, "full",
                             causal=False)
            b_, s = x.shape[:2]
            x = x + a.reshape(b_, s, -1) @ p["attn"]["wo"]
            x = x + _mlp(cfg, p["mlp"], _norm(cfg, p, "ln2", x))
            return x, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return _norm(cfg, params, "enc_final_ln", x)

    # ---------------------------------------------------------------- decode
    def _cross_kv(self, params, enc_out):
        cfg = self.cfg
        b_, f = enc_out.shape[:2]

        def one(p):
            k = (enc_out @ p["wk"]).reshape(b_, f, cfg.n_kv_heads, cfg.hd)
            v = (enc_out @ p["wv"]).reshape(b_, f, cfg.n_kv_heads, cfg.hd)
            return {"xk": k, "xv": v}

        return jax.vmap(one)(params["dec_blocks"]["xattn"])

    def _decoder(self, params, x, pos, cache, t, mode, cross_kv,
                 remat: bool):
        cfg = self.cfg

        def body(x, xs):
            p, c, xkv = xs
            h = _norm(cfg, p, "ln1", x)
            self_c = ({"k": c["k"], "v": c["v"]} if c is not None else None)
            a, nc = _gqa_attn(cfg, p["attn"], h, pos, self_c, t, mode)
            b_, s = x.shape[:2]
            x = x + a.reshape(b_, s, -1) @ p["attn"]["wo"]
            # cross attention over the (fixed) encoder frames
            h = _norm(cfg, p, "ln_x", x)
            q = (h @ p["xattn"]["wq"]).reshape(b_, s, cfg.n_heads, cfg.hd)
            if mode == "decode":
                xa = decode_attention(q, xkv["xk"], xkv["xv"],
                                      kv_len=xkv["xk"].shape[1])
            else:
                xa = attention(q, xkv["xk"], xkv["xv"], causal=False)
            x = x + xa.reshape(b_, s, -1) @ p["xattn"]["wo"]
            x = x + _mlp(cfg, p["mlp"], _norm(cfg, p, "ln2", x))
            return x, nc

        if remat and mode == "full":
            body = jax.checkpoint(body)
        x, nc = jax.lax.scan(body, x, (params["dec_blocks"], cache, cross_kv))
        return _norm(cfg, params, "final_ln", x), nc

    def _embed_tokens(self, params, tokens, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        return x + params["pos_embed"][jnp.clip(pos, 0, cfg.max_position - 1)]

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, remat: bool = True):
        enc_out = self.encode(params, batch["frames"], remat=remat)
        cross_kv = self._cross_kv(params, enc_out)
        tokens = batch["tokens"]
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None],
                               tokens.shape)
        x = self._embed_tokens(params, tokens, pos)
        h, _ = self._decoder(params, x, pos, None, None, "full", cross_kv,
                             remat)
        return chunked_softmax_xent(h, params["unembed"], batch["labels"])

    # ----------------------------------------------------------------- cache
    def _cache_tree(self, batch_size, max_len, make):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        F = cfg.encoder.n_frames
        return {"k": make((L, batch_size, max_len, kvh, hd), dt),
                "v": make((L, batch_size, max_len, kvh, hd), dt),
                "xk": make((L, batch_size, F, kvh, hd), dt),
                "xv": make((L, batch_size, F, kvh, hd), dt)}

    def cache_spec(self, batch_size, max_len):
        return self._cache_tree(batch_size, max_len, jax.ShapeDtypeStruct)

    def init_cache(self, batch_size, max_len):
        return self._cache_tree(batch_size, max_len, jnp.zeros)

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch, cache):
        enc_out = self.encode(params, batch["frames"])
        cross_kv = self._cross_kv(params, enc_out)
        tokens = batch["tokens"]
        s = tokens.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], tokens.shape)
        x = self._embed_tokens(params, tokens, pos)
        self_cache = {"k": cache["k"], "v": cache["v"]}
        h, nc = self._decoder(params, x, pos, self_cache, None, "prefill",
                              cross_kv, False)
        cache = {"k": nc["k"], "v": nc["v"],
                 "xk": cross_kv["xk"].astype(cache["xk"].dtype),
                 "xv": cross_kv["xv"].astype(cache["xv"].dtype)}
        return logits_last(h[:, -1], params["unembed"]), cache

    def decode_step(self, params, batch, cache):
        t = batch["t"]
        pos = jnp.broadcast_to(t[None, None], batch["token"].shape
                               ).astype(jnp.int32)
        x = self._embed_tokens(params, batch["token"], pos)
        self_cache = {"k": cache["k"], "v": cache["v"]}
        cross_kv = {"xk": cache["xk"], "xv": cache["xv"]}
        h, nc = self._decoder(params, x, pos, self_cache, t, "decode",
                              cross_kv, False)
        cache = dict(cache, k=nc["k"], v=nc["v"])
        return logits_last(h[:, -1], params["unembed"]), cache
