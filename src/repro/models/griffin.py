"""Griffin / RecurrentGemma (De et al., arXiv:2402.19427).

Residual blocks with a temporal-mixing layer (RG-LRU recurrent block or
local sliding-window attention, pattern rec:rec:attn) followed by a GeGLU
MLP block.

Recurrent block: x -> [gelu gate branch] ⊙ [causal conv1d(width 4) ->
RG-LRU] -> out projection.

RG-LRU:  r_t = sigmoid(W_r x_t + b_r),  i_t = sigmoid(W_i x_t + b_i)
         a_t = exp(-c * softplus(Λ) * r_t)          (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

Training/prefill evaluate the linear recurrence with an associative scan
(log-depth on TPU); decode is the exact one-step cell. Decode state per
recurrent layer is (h [B, d_rnn], conv tail [B, w-1, d_rnn]) — O(1) in
sequence length, so long_500k is admissible.

Pattern remainder: 26 layers = 8 full (rec, rec, attn) periods + 2
remainder rec layers; the remainder gets its own parameter stack.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .common import chunked_softmax_xent, logits_last
from .transformer import (ParamBuilder, _add_attn_params, _add_mlp_params,
                          _add_norm_params, _gqa_attn, _mlp, _norm)

LRU_C = 8.0


# ------------------------------------------------------------------- RG-LRU

def _add_rec_params(b: ParamBuilder, cfg: ArchConfig, path: str, stack):
    d, dr, w = cfg.d_model, cfg.d_rnn, cfg.conv_width
    _add_norm_params(b, cfg, path + "/ln", d, stack)
    b.matrix(path + "/w_gate", d, dr, stack=stack)
    b.matrix(path + "/w_x", d, dr, stack=stack)
    b.matrix(path + "/conv_w", w, dr, stack=stack, scale=1.0 / math.sqrt(w))
    b.vector(path + "/conv_b", dr, stack=stack, value=0.0)
    b.matrix(path + "/w_r", dr, dr, stack=stack)
    b.vector(path + "/b_r", dr, stack=stack, value=0.0)
    b.matrix(path + "/w_i", dr, dr, stack=stack)
    b.vector(path + "/b_i", dr, stack=stack, value=0.0)
    # Λ init so that a^c ∈ [0.9, 0.999] at r = 1 (paper §2.4)
    b.vector(path + "/lam", dr, stack=stack, value=0.649)  # softplus^-1(?) set below
    b.matrix(path + "/w_out", dr, d, stack=stack, scale=1.0 / math.sqrt(dr))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv. x [B,S,D], w [W,D]. tail [B,W-1,D] (decode
    state: previous inputs). Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return y + b, new_tail


def rglru(x: jax.Array, p: dict, h0: jax.Array | None):
    """RG-LRU over a sequence. x [B,S,Dr]; h0 [B,Dr] or None.
    Returns (y [B,S,Dr], h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32)
                       + p["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    if h0 is not None:
        # fold the carried state in as a virtual timestep 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0.astype(jnp.float32)[:, None], gated],
                                axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step(x: jax.Array, p: dict, h: jax.Array):
    """One RG-LRU step. x [B,Dr], h [B,Dr] (f32)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_r"].astype(jnp.float32)
                       + p["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h_new = a * h.astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return h_new.astype(x.dtype), h_new


def _rec_block(cfg: ArchConfig, p: dict, x: jax.Array, cache, mode: str):
    h = _norm(cfg, p, "ln", x)
    gate = jax.nn.gelu((h @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    xr_in = h @ p["w_x"]
    tail = cache["conv"] if mode == "decode" else None
    xr, new_tail = _causal_conv(xr_in, p["conv_w"], p["conv_b"], tail)
    if mode == "decode":
        y, h_new = rglru_step(xr[:, 0], p, cache["h"])
        y = y[:, None]
        new_cache = {"h": h_new, "conv": new_tail}
    else:
        y, h_last = rglru(xr, p, None)
        new_cache = None
        if mode == "prefill":
            W = cfg.conv_width
            pad_in = jnp.pad(xr_in, ((0, 0), (W - 1, 0), (0, 0)))
            new_cache = {"h": h_last.astype(jnp.float32),
                         "conv": pad_in[:, -(W - 1):]}
    out = (gate * y) @ p["w_out"]
    return x + out, new_cache


def _attn_block(cfg: ArchConfig, p: dict, x: jax.Array, pos, cache, t,
                mode: str):
    h = _norm(cfg, p, "ln", x)
    a_out, new_cache = _gqa_attn(cfg, p["attn"], h, pos, cache, t, mode)
    b_, s = x.shape[:2]
    a_out = a_out.reshape(b_, s, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
    return x + a_out, new_cache


def _mlp_block(cfg: ArchConfig, p: dict, x: jax.Array):
    return x + _mlp(cfg, p["mlp"], _norm(cfg, p, "ln", x))


# -------------------------------------------------------------------- model

class GriffinModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        pat = cfg.block_pattern
        self.pat = pat
        self.n_periods = cfg.n_layers // len(pat)
        self.rem = tuple(pat[:cfg.n_layers % len(pat)])
        self.n_rec = sum(1 for k in pat if k == "rec")
        self.n_attn = sum(1 for k in pat if k == "attn")

    def init(self, key):
        cfg = self.cfg
        b = ParamBuilder(key, jnp.dtype(cfg.dtype))
        b.embed("embed", cfg.vocab, cfg.d_model)
        _add_norm_params(b, cfg, "final_ln", cfg.d_model)

        def add_slots(prefix, nper, pattern):
            n_rec = sum(1 for k in pattern if k == "rec")
            n_attn = sum(1 for k in pattern if k == "attn")
            if n_rec:
                _add_rec_params(b, cfg, f"{prefix}/rec", (nper, n_rec))
            if n_attn:
                stack = (nper, n_attn)
                _add_norm_params(b, cfg, f"{prefix}/attn/ln", cfg.d_model,
                                 stack)
                _add_attn_params(b, cfg, f"{prefix}/attn/attn", stack)
            stack = (nper, len(pattern))
            _add_norm_params(b, cfg, f"{prefix}/mlp/ln", cfg.d_model, stack)
            _add_mlp_params(b, cfg, f"{prefix}/mlp/mlp", cfg.d_model,
                            cfg.d_ff, stack)

        add_slots("blocks", self.n_periods, self.pat)
        if self.rem:
            add_slots("rem", 1, self.rem)
        return b.params, b.metas

    # ---------------------------------------------------------------- run
    def _run_group(self, group_p, pattern, x, pos, cache, t, mode, remat):
        cfg = self.cfg

        def period(x, xs):
            p, c = xs
            ir = ia = 0
            nc_rec, nc_attn = [], []
            for j, kind in enumerate(pattern):
                if kind == "rec":
                    pj = jax.tree.map(lambda a: a[ir], p["rec"])
                    cj = (jax.tree.map(lambda a: a[ir], c["rec"])
                          if c else None)
                    x, nc = _rec_block(cfg, pj, x, cj, mode)
                    nc_rec.append(nc)
                    ir += 1
                else:
                    pj = jax.tree.map(lambda a: a[ia], p["attn"])
                    cj = (jax.tree.map(lambda a: a[ia], c["attn"])
                          if c else None)
                    x, nc = _attn_block(cfg, pj, x, pos, cj, t, mode)
                    nc_attn.append(nc)
                    ia += 1
                pm = jax.tree.map(lambda a: a[j], p["mlp"])
                x = _mlp_block(cfg, pm, x)
            stk = lambda lst: (jax.tree.map(lambda *a: jnp.stack(a), *lst)
                               if lst and lst[0] is not None else None)
            return x, {"rec": stk(nc_rec), "attn": stk(nc_attn)}

        if remat and mode == "full":
            period = jax.checkpoint(period)
        return jax.lax.scan(period, x, (group_p, cache))

    def _run(self, params, x, pos, cache, t, mode, remat):
        cfg = self.cfg
        new_cache = {} if cache is not None else None
        x, nc = self._run_group(params["blocks"], self.pat, x, pos,
                                cache["blocks"] if cache else None, t, mode,
                                remat)
        if new_cache is not None:
            new_cache["blocks"] = nc
        if self.rem:
            x, nc = self._run_group(params["rem"], self.rem, x, pos,
                                    cache["rem"] if cache else None, t, mode,
                                    remat)
            if new_cache is not None:
                new_cache["rem"] = nc
        return _norm(cfg, params, "final_ln", x), new_cache

    def loss(self, params, batch, *, remat: bool = True):
        x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
        h, _ = self._run(params, x, pos, None, None, "full", remat)
        # RecurrentGemma ties the unembedding to the input embedding
        return chunked_softmax_xent(h, params["embed"].T, batch["labels"])

    # ----------------------------------------------------------------- cache
    def _group_cache(self, pattern, nper, batch_size, max_len, make):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        n_rec = sum(1 for k in pattern if k == "rec")
        n_attn = sum(1 for k in pattern if k == "attn")
        cap = min(cfg.window or max_len, max_len)
        out = {}
        out["rec"] = {"h": make((nper, n_rec, batch_size, cfg.d_rnn),
                                jnp.float32),
                      "conv": make((nper, n_rec, batch_size,
                                    cfg.conv_width - 1, cfg.d_rnn), dt)} \
            if n_rec else None
        out["attn"] = {
            "k": make((nper, n_attn, batch_size, cap, cfg.n_kv_heads,
                       cfg.hd), dt),
            "v": make((nper, n_attn, batch_size, cap, cfg.n_kv_heads,
                       cfg.hd), dt)} if n_attn else None
        return out

    def _cache_tree(self, batch_size, max_len, make):
        out = {"blocks": self._group_cache(self.pat, self.n_periods,
                                           batch_size, max_len, make)}
        if self.rem:
            out["rem"] = self._group_cache(self.rem, 1, batch_size,
                                           max_len, make)
        return out

    def cache_spec(self, batch_size, max_len):
        return self._cache_tree(batch_size, max_len, jax.ShapeDtypeStruct)

    def init_cache(self, batch_size, max_len):
        return self._cache_tree(batch_size, max_len, jnp.zeros)

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch, cache):
        x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
        h, cache = self._run(params, x, pos, cache, None, "prefill", False)
        return logits_last(h[:, -1], params["embed"].T), cache

    def decode_step(self, params, batch, cache):
        t = batch["t"]
        x = params["embed"][batch["token"]]
        pos = jnp.broadcast_to(t[None, None], x.shape[:2]).astype(jnp.int32)
        h, cache = self._run(params, x, pos, cache, t, "decode", False)
        return logits_last(h[:, -1], params["embed"].T), cache
