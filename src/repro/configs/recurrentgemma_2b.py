"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427].

26L, d_model 2560, 10 heads (MQA kv=1), d_ff 7680 (GeGLU), vocab 256000,
RG-LRU recurrent width 2560, conv width 4, local attention window 2048,
block pattern (rec, rec, attn). O(1)/O(window) state => long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", source="arXiv:2402.19427",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, rope="rope", rope_base=10000.0, window=2048,
    norm="rmsnorm", act="geglu", d_rnn=2560, conv_width=4,
    block_pattern=("rec", "rec", "attn"),
)
