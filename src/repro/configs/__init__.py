"""Architecture registry: ``get_config(name)`` / ``ARCHS``.

One module per assigned architecture (exact published specs, source cited
in each file) plus the paper's own NanoGPT-124M experimental model.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeSpec

ARCHS = (
    "qwen2-vl-7b",
    "whisper-small",
    "starcoder2-15b",
    "xlstm-1.3b",
    "mixtral-8x7b",
    "qwen2.5-3b",
    "granite-3-2b",
    "deepseek-v3-671b",
    "mistral-large-123b",
    "recurrentgemma-2b",
    "nanogpt-124m",
)


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {ARCHS}")
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeSpec", "get_config"]
