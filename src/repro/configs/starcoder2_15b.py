"""StarCoder2-15B [arXiv:2402.19173].

40L, d_model 6144, 48 heads (GQA kv=4), d_ff 24576, vocab 49152, RoPE
(base 1e5), sliding-window 4096, LayerNorm + GELU, linear-layer bias.
The 4096 sliding window makes long_500k decode admissible (ring cache).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense", source="arXiv:2402.19173",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab=49152, qkv_bias=True, rope="rope", rope_base=1e5, window=4096,
    norm="layernorm", act="gelu", norm_eps=1e-5,
)
