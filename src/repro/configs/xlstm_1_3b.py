"""xLSTM-1.3B [arXiv:2405.04517].

48 blocks, d_model 2048, 4 heads, d_ff 0 (the mLSTM up/down projection
plays the FFN role), vocab 50304. Block pattern 7:1 mLSTM:sLSTM
(xLSTM[7:1]), matrix-memory mLSTM with chunkwise-parallel training and
O(1) recurrent decode state => long_500k admissible.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm", source="arXiv:2405.04517",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, rope="none", norm="rmsnorm", act="swiglu",
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
)
