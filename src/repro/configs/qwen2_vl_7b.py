"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

28L, d_model 3584, 28 heads (GQA kv=4), d_ff 18944, vocab 152064, QKV bias,
M-RoPE with (temporal, height, width) sections (16, 24, 24). The ViT vision
encoder + projector is a STUB: ``input_specs`` feeds precomputed patch
embeddings [B, S, d_model] plus 3-channel M-RoPE position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm", source="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, qkv_bias=True, rope="mrope", rope_base=1e6,
    mrope_sections=(16, 24, 24), norm="rmsnorm", act="swiglu",
)
