"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437].

61L, d_model 7168, 128 heads, MLA (q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v 128), MoE: 1 shared + 256 routed experts top-8,
d_expert 2048, first 3 layers dense (d_ff 18432), MTP head, vocab 129280.
MLA is full attention => long_500k skipped.
"""
from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, rope="rope", rope_base=10000.0,
    norm="rmsnorm", act="swiglu",
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1),
    moe_start_layer=3, dense_ff=18432,
    mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    mtp=True,
)
