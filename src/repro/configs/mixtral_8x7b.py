"""Mixtral-8x7B [arXiv:2401.04088].

32L, d_model 4096, 32 heads (GQA kv=8), MoE: 8 experts, top-2,
d_expert 14336 (the dense-equivalent d_ff), sliding window 4096
(original Mixtral config), vocab 32000, RMSNorm + SwiGLU.
"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", source="arXiv:2401.04088",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, rope="rope", rope_base=1e6, window=4096,
    norm="rmsnorm", act="swiglu",
    moe=MoECfg(n_experts=8, top_k=2, d_expert=14336),
)
