"""NanoGPT-124M — the paper's own experimental model (Karpathy 2023,
paper §5: 12L, d_model 768, 12 heads, d_ff 3072, GPT-2 vocab 50304,
sequence 1024, tied embeddings). Used by the Figure 1/2 and Table 2
benchmark reproductions.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nanogpt-124m", family="dense", source="github:karpathy/nanoGPT",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=50304, rope="learned", norm="layernorm", act="gelu",
    norm_eps=1e-5, tied_embeddings=True, max_position=1024,
)
