"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family card].

36L, d_model 2048, 16 heads (GQA kv=2), d_ff 11008, vocab 151936,
QKV bias, RoPE base 1e6, RMSNorm + SwiGLU, tied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense", source="hf:Qwen/Qwen2.5-3B",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab=151936, qkv_bias=True, rope="rope", rope_base=1e6,
    norm="rmsnorm", act="swiglu", tied_embeddings=True,
)
