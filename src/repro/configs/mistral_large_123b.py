"""Mistral-Large-Instruct-2407 (123B) [hf:mistralai/Mistral-Large-Instruct-2407].

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768,
head_dim 128, RoPE base 1e6, RMSNorm + SwiGLU. Deepest assigned arch;
pure full attention => long_500k skipped (documented in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768, head_dim=128, rope="rope", rope_base=1e6,
    norm="rmsnorm", act="swiglu",
)
