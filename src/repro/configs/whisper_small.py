"""Whisper-small decoder + encoder backbone [arXiv:2212.04356].

12L enc + 12L dec, d_model 768, 12 heads (MHA: kv=12), d_ff 3072,
vocab 51865, LayerNorm + GELU, learned decoder positions. The mel
spectrogram + conv frontend is a STUB: ``input_specs`` feeds precomputed
frame embeddings [B, 1500, d_model] to the encoder.
"""
from .base import ArchConfig, EncoderCfg

CONFIG = ArchConfig(
    name="whisper-small", family="audio", source="arXiv:2212.04356",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, rope="learned", norm="layernorm", act="gelu",
    norm_eps=1e-5, encoder=EncoderCfg(n_layers=12, n_frames=1500),
    frontend="audio", max_position=32768,
)
