"""Architecture + input-shape config system.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke
variants come from ``.reduced()`` and depth-scaled roofline variants from
``.with_depth(k)`` (both preserve the family structure: block patterns,
MoE topology, MLA dims scale coherently).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int            # expert FFN hidden dim
    n_shared: int = 0        # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLACfg:
    """DeepSeek-V3 multi-head latent attention dims."""
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class EncoderCfg:
    """Whisper-style encoder (consumes stub frame embeddings)."""
    n_layers: int = 12
    n_frames: int = 1500


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""                  # citation
    head_dim: int | None = None       # default d_model // n_heads
    rope: str = "rope"                # rope | mrope | learned | none
    rope_base: float = 10000.0
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    qkv_bias: bool = False
    window: int | None = None         # sliding-window attention
    moe: MoECfg | None = None
    moe_start_layer: int = 0          # leading dense layers (DeepSeek: 3)
    dense_ff: int | None = None       # FFN dim of those dense layers
    mla: MLACfg | None = None
    mtp: bool = False                 # multi-token-prediction head
    tied_embeddings: bool = False
    block_pattern: tuple[str, ...] | None = None  # per-period kinds (ssm/hybrid)
    d_rnn: int | None = None          # recurrent width (RG-LRU)
    conv_width: int = 4
    encoder: EncoderCfg | None = None
    frontend: str | None = None       # vision | audio (stubbed)
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | gelu | geglu
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    max_position: int = 32768         # learned-position table size if rope=="learned"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else \
            self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is admissible (O(1)/O(window) state)."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 effective layers (1 period for patterned
        families), d_model <= 256, <=4 experts, small vocab; same family
        structure."""
        kw: dict = dict(dtype="float32", norm_eps=self.norm_eps)
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kvh = min(self.n_kv_heads, heads)
        heads = (heads // kvh) * kvh
        kw.update(n_layers=2 if self.block_pattern is None else len(self.block_pattern),
                  d_model=d, n_heads=heads, n_kv_heads=kvh,
                  head_dim=d // heads if self.head_dim else None,
                  d_ff=min(self.d_ff, 512) if self.d_ff else 0,
                  vocab=min(self.vocab, 512),
                  window=min(self.window, 64) if self.window else None,
                  max_position=512)
        if self.moe:
            kw["moe"] = replace(self.moe, n_experts=min(self.moe.n_experts, 4),
                                top_k=min(self.moe.top_k, 2),
                                d_expert=min(self.moe.d_expert, 128))
            kw["moe_start_layer"] = min(self.moe_start_layer, 1)
            kw["dense_ff"] = min(self.dense_ff, 256) if self.dense_ff else None
        if self.mla:
            kw["mla"] = MLACfg(q_lora=64, kv_lora=32, qk_nope=d // heads,
                               qk_rope=16, v_dim=d // heads)
        if self.rope == "mrope":
            # rescale the M-RoPE sections to the reduced head_dim // 2
            d2 = (d // heads) // 2
            tot = sum(self.mrope_sections)
            secs = [max(1, (s * d2) // tot) for s in self.mrope_sections[:-1]]
            secs.append(d2 - sum(secs))
            kw["mrope_sections"] = tuple(secs)
        if self.encoder:
            kw["encoder"] = EncoderCfg(n_layers=2, n_frames=64)
        if self.d_rnn:
            kw["d_rnn"] = d
        return replace(self, **kw)

    def with_depth(self, periods: int) -> "ArchConfig":
        """Depth-scaled variant for roofline extrapolation: `periods`
        repetitions of the block pattern (or layers for uniform stacks),
        keeping widths exact."""
        if self.block_pattern is not None:
            return replace(self, n_layers=periods * len(self.block_pattern))
        if self.moe and self.moe_start_layer:
            # keep 1 dense layer, scale MoE layers
            return replace(self, moe_start_layer=1,
                           n_layers=1 + periods)
        return replace(self, n_layers=periods)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
