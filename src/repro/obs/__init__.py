"""Runtime observability (DESIGN.md §10): in-graph metrics, trace
spans, and the unified JSONL metrics sink.

Three pieces, importable leaf-first (nothing here imports repro.core —
the optimizer imports us):

* ``obs.metrics``  — ``MetricSet`` pytree + the norm helpers the step
  collects per layer-plan leaf / NS bucket (gated by
  ``EF21MuonConfig.metrics``; metrics-off lowers identically).
* ``obs.trace``    — ``phase_span``/``wire_stage_span`` names for the
  five optimizer phases and every staged wire collective, plus the
  host-side ``span`` timer for non-jit phases.
* ``obs.sink``     — schema-versioned ``MetricsWriter`` JSONL sink with
  an async flush thread; one validator covers live training logs,
  dry-run rows and the committed BENCH trajectories.
"""
from .metrics import (MetricSet, leaf_names, orth_residual, rel_error,
                      worker_mean_norm)
from .sink import (SCHEMA, MetricsWriter, SchemaError, config_hash,
                   run_manifest, validate_bench_file, validate_jsonl,
                   validate_record, write_bench_artifact)
from .trace import (PHASE_SPANS, RECORDER, SpanRecorder, phase_span, span,
                    span_summary, wire_stage_span)

__all__ = [
    "MetricSet", "leaf_names", "orth_residual", "rel_error",
    "worker_mean_norm",
    "SCHEMA", "MetricsWriter", "SchemaError", "config_hash",
    "run_manifest", "validate_bench_file", "validate_jsonl",
    "validate_record", "write_bench_artifact",
    "PHASE_SPANS", "RECORDER", "SpanRecorder", "phase_span", "span",
    "span_summary", "wire_stage_span",
]
