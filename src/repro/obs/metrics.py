"""In-graph metrics — a ``MetricSet`` pytree collected inside the jitted
step (DESIGN.md §10).

A MetricSet is an ordered name -> scalar mapping registered as a pytree,
so the optimizer can thread it through the step phases and return it in
``aux`` without a host sync: every value is a traced ``jnp`` scalar
(static accounting numbers like wire bytes become constants in the
graph). Collection is gated by ``EF21MuonConfig.metrics`` — the
metrics-off arm builds no MetricSet and lowers identically to a build
without this module.

Metric names are ``/``-separated taxonomies (DESIGN.md §10):

  ef/err_norm/<leaf>        ‖M_j - G_j'‖   post-update EF21 error, mean
                            over workers of the per-worker F-norm
  ef/rel_err/<leaf>         ‖C(v)-v‖/‖v‖   compression relative error of
                            v = M_j - G_j (0 where ‖v‖ == 0)
  ef/momentum_norm/<leaf>   ‖M_j‖          worker-mean momentum norm
  efp/err_norm/<leaf>       ‖X - W‖        EF21-P server model-estimate
                            error (s2w leg only)
  ns/orth_residual/<bucket> ‖G - I‖_F      Newton-Schulz orthogonality
                            residual, G the small-side gram of the
                            bucket direction, mean over the batch
  wire/...                  static per-direction wire bytes + stage count
  part/worker_version_lag_max   max s2w version lag across workers after
                            this round's rejoin (§13; 0 = all current)
  resync/replayed           workers that caught up this step by replaying
                            missed rounds from the ring (§13)
  resync/full               workers that rejoined via the full W resync
                            (lag > R)
  supervisor/retries        host-side: cumulative supervised-step
                            re-dispatches, merged into the step record by
                            the train CLI (never in-graph)

The helpers here are pure functions of tensors the step already
computes — adding them never feeds back into the update, which is what
makes the metrics-on arm value-bit-equal to metrics-off.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

_NAME_RE = re.compile(r"^[A-Za-z0-9_.+\-]+(/[A-Za-z0-9_.+\-]+)*$")


class MetricSet:
    """Ordered mapping of metric name -> scalar, registered as a pytree
    (names are static treedef data, values are leaves)."""

    def __init__(self, values: dict | None = None):
        self._values: dict = dict(values or {})

    def add(self, name: str, value) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        if name in self._values:
            raise ValueError(f"duplicate metric {name!r}")
        self._values[name] = jnp.asarray(value)

    def names(self) -> tuple[str, ...]:
        return tuple(self._values)

    def as_dict(self) -> dict:
        return dict(self._values)

    def host_floats(self) -> dict[str, float]:
        """Device-get every value (the one intentional sync point — the
        sink calls this every N steps, never the step itself)."""
        return {k: float(v) for k, v in
                zip(self._values, jax.device_get(list(self._values.values())))}

    def __len__(self) -> int:
        return len(self._values)

    def __getitem__(self, name: str):
        return self._values[name]

    def __contains__(self, name: str) -> bool:
        return name in self._values

    def __repr__(self) -> str:
        return f"MetricSet({list(self._values)})"


def _flatten(ms: MetricSet):
    return tuple(ms._values.values()), tuple(ms._values)


def _unflatten(names, values) -> MetricSet:
    return MetricSet(dict(zip(names, values)))


jax.tree_util.register_pytree_node(MetricSet, _flatten, _unflatten)


# ------------------------------------------------------------- norm helpers

def worker_mean_norm(x, lead: int = 1):
    """Mean over the ``lead`` leading (worker) dims of the F-norm over
    everything else — the per-layer norm the paper plots per worker."""
    x = jnp.asarray(x, jnp.float32)
    axes = tuple(range(lead, x.ndim))
    return jnp.mean(jnp.sqrt(jnp.sum(jnp.square(x), axis=axes)))


def rel_error(num, den, lead: int = 1):
    """Worker-mean of ‖num‖/‖den‖ per worker, 0 where ‖den‖ == 0."""
    num = jnp.asarray(num, jnp.float32)
    den = jnp.asarray(den, jnp.float32)
    axes = tuple(range(lead, num.ndim))
    n = jnp.sqrt(jnp.sum(jnp.square(num), axis=axes))
    d = jnp.sqrt(jnp.sum(jnp.square(den), axis=axes))
    return jnp.mean(jnp.where(d > 0, n / jnp.where(d > 0, d, 1.0), 0.0))


def orth_residual(d_b):
    """NS orthogonality residual of a bucket direction ``[B, m, n]``:
    mean over the batch of ‖G - I_k‖_F with G the gram over the smaller
    side (D Dᵀ for m <= n, Dᵀ D otherwise) — the quantity Newton-Schulz
    drives to 0 as the iterate approaches U Vᵀ."""
    d = jnp.asarray(d_b, jnp.float32)
    m, n = d.shape[-2:]
    if m <= n:
        g = jnp.einsum("...ij,...kj->...ik", d, d)
    else:
        g = jnp.einsum("...ji,...jk->...ik", d, d)
    k = min(m, n)
    r = g - jnp.eye(k, dtype=jnp.float32)
    return jnp.mean(jnp.sqrt(jnp.sum(jnp.square(r), axis=(-2, -1))))


def leaf_names(params) -> tuple[str, ...]:
    """Stable ``/``-joined key-path name per leaf of ``params``, in
    treedef (flatten) order — the <leaf> component of metric names."""
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(params)[0]) \
        if jax.tree_util.tree_flatten_with_path(params)[0] else ((), ())
    out = []
    for path in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                raw = str(p.key)
            elif hasattr(p, "idx"):
                raw = str(p.idx)
            elif hasattr(p, "name"):
                raw = str(p.name)
            else:
                raw = str(p)
            parts.append(re.sub(r"[^A-Za-z0-9_.+\-]", "-", raw))
        out.append("/".join(parts) if parts else "param")
    return tuple(out)
