"""Trace spans — names for the step's phase structure (DESIGN.md §10).

Two span kinds, one naming convention:

* ``phase_span(name, graph=...)`` wraps a block of *traced* optimizer
  code. It always enters a ``jax.profiler.TraceAnnotation`` (a host-side
  TraceMe: real timing when the step runs eagerly, trace-time-only noise
  under jit — never a lowering change), and, when ``graph`` is true,
  additionally a ``jax.named_scope`` so the ops lowered inside carry the
  span name as op metadata and an xprof capture of the jitted step shows
  the §8 overlap structure by name. ``graph`` is gated by
  ``EF21MuonConfig.trace_spans`` because op metadata appears in the
  compiled HLO text — the spans-off arm must lower byte-identical to a
  build without this module.

* ``span(name)`` times a *host-side* (non-jit) phase — plan build,
  layout memoisation, checkpoint I/O — into the process-wide
  ``SpanRecorder`` (and the same TraceAnnotation, so host phases show up
  in profiler captures too). ``span_summary()`` renders the recorder as
  rows for the metrics sink / the train CLI's end-of-run table.

Span names are the contract the slow profiler test asserts against:
``PHASE_SPANS`` for the five algorithm phases of ``core/muon.py``, and
``wire_stage_span(direction, k)`` for stage ``k``'s gather/broadcast in
``dist/pipeline.py``'s issue order.
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import OrderedDict

import jax

# The five algorithm phases of EF21-Muon (core/muon.py, DESIGN.md §5),
# in dataflow order. One span per phase, staged or monolithic.
PHASE_SPANS = (
    "ef21/p1_s2w_update",    # EF21-P model estimate + s2w broadcast
    "ef21/p2_grads",         # per-worker grads at W (vmap, no comm)
    "ef21/p3_ef_compress",   # momentum + EF21 compress R_j = C_D(M_j-G_j)
    "ef21/p4_wire_recv",     # payload gathers issued + server receive
    "ef21/p5_lmo",           # layer-wise LMO (bucketed Newton-Schulz)
)


def wire_stage_span(direction: str, k: int) -> str:
    """Span name of stage ``k``'s u8 collective: ``direction`` is
    ``"w2s"`` (payload all-gather) or ``"s2w"`` (update broadcast)."""
    if direction not in ("w2s", "s2w"):
        raise ValueError(f"direction must be w2s|s2w, got {direction!r}")
    return f"wire/{direction}/stage{k}"


@contextlib.contextmanager
def phase_span(name: str, graph: bool = False):
    """Span around traced optimizer code. Host TraceAnnotation always
    (lowering-neutral); ``jax.named_scope`` only when ``graph`` — the
    op-metadata arm the HLO-identity guard keeps off by default."""
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.profiler.TraceAnnotation(name))
        if graph:
            stack.enter_context(jax.named_scope(name))
        yield


class SpanRecorder:
    """Thread-safe accumulator of host-side span wall times."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: OrderedDict[str, list] = OrderedDict()

    def record(self, name: str, dur_s: float) -> None:
        with self._lock:
            ent = self._spans.setdefault(name, [0, 0.0, 0.0])
            ent[0] += 1
            ent[1] += dur_s
            ent[2] = max(ent[2], dur_s)

    def summary(self) -> list[dict]:
        """One row per span name (insertion order): count / total / max."""
        with self._lock:
            return [{"name": n, "count": c, "total_s": round(t, 6),
                     "max_s": round(mx, 6)}
                    for n, (c, t, mx) in self._spans.items()]

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()


# Process-wide recorder: host phases are rare (plan builds, checkpoint
# I/O, per-step host work) and the CLI summary wants them all in one
# place. Tests snapshot/clear around themselves.
RECORDER = SpanRecorder()


@contextlib.contextmanager
def span(name: str, recorder: SpanRecorder | None = None):
    """Wall-time a host-side (non-jit) phase into the recorder, and mark
    it as a TraceAnnotation so profiler captures see it too."""
    rec = RECORDER if recorder is None else recorder
    t0 = time.perf_counter()
    with jax.profiler.TraceAnnotation(name):
        try:
            yield
        finally:
            rec.record(name, time.perf_counter() - t0)


def span_summary(recorder: SpanRecorder | None = None) -> list[dict]:
    return (RECORDER if recorder is None else recorder).summary()
