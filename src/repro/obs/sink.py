"""Unified metrics sink — schema-versioned JSONL (DESIGN.md §10).

One record schema from dry-run prediction to live measurement: the
trainer CLI, the multi-pod dry-run and the benchmarks all emit through
``MetricsWriter``, so a single validator covers ``results/dryrun.jsonl``,
the committed ``BENCH_*.json`` trajectories and live training logs.

Envelope (every record is one JSON object per line):

    {"schema": "repro.metrics/v1", "kind": <kind>, ...kind fields...}

Kinds and their required fields (``validate_record``):

    manifest  config_hash:str, mesh, git_rev     — run header, written
              first (plus jax/schema versions, argv)
    step      step:int, loss:number              — one training step;
              optional metrics:{name: number} from MetricSet
    span      name:str, count:int, total_s:num   — host span summary row
    summary   spans:list[span]                   — end-of-run rollup;
              optional ef_summary rows
    dryrun    arch/shape/mesh/tag:str, status    — launch/dryrun rows
    bench     bench:str                          — benchmarks/* rows
    lint      rule/cell/level/message:str        — analysis.lint findings
              (§12); optional data:{...} rule payload
    recovery  step:int, event:str, attempt:int  — supervisor recovery
              events (§13): event in {start, resume, timeout, retry,
              reload, checkpoint, gave_up}

Legacy rows (pre-v1, no ``schema`` key) validate structurally: the kind
is inferred (``bench`` key => bench, arch/shape/mesh/tag => dryrun), so
the committed history stays valid without rewriting it.

The writer is async: records go to a queue, a daemon thread batches
them to disk and flushes every ``flush_every`` records (and on close) —
the training loop never blocks on file I/O. Values may be jax/numpy
scalars; they are converted in the writer thread.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import subprocess
import sys
import threading
import time
import warnings

SCHEMA = "repro.metrics/v1"

_NUM = (int, float)

# kind -> {field: type-check}; a check is a type tuple or a callable
REQUIRED: dict[str, dict] = {
    "manifest": {"config_hash": str, "mesh": object, "git_rev": object},
    "step": {"step": int, "loss": _NUM},
    "span": {"name": str, "count": int, "total_s": _NUM},
    "summary": {"spans": list},
    "dryrun": {"arch": str, "shape": str, "mesh": str, "tag": str,
               "status": str},
    "bench": {"bench": str},
    "lint": {"rule": str, "cell": str, "level": str, "message": str},
    "recovery": {"step": int, "event": str, "attempt": int},
}


class SchemaError(ValueError):
    pass


def _infer_kind(rec: dict) -> str | None:
    """Kind of a legacy (pre-envelope) record, or None."""
    if "bench" in rec:
        return "bench"
    if all(k in rec for k in ("arch", "shape", "mesh", "tag")):
        return "dryrun"
    return None


def validate_record(rec, kind: str | None = None) -> str:
    """Validate one record; returns its kind. Raises SchemaError."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record must be a dict, got {type(rec).__name__}")
    enveloped = "schema" in rec
    if enveloped and rec["schema"] != SCHEMA:
        raise SchemaError(f"unknown schema {rec['schema']!r}")
    # "kind" is the envelope discriminator only on schema-stamped
    # records; legacy rows may use it as a plain domain field (the
    # committed ns bench rows do), so there it never drives or fights
    # the structural inference.
    k = kind or (rec.get("kind") if enveloped else None) or _infer_kind(rec)
    if k is None:
        raise SchemaError(f"cannot infer record kind: keys={sorted(rec)[:8]}")
    if k not in REQUIRED:
        raise SchemaError(f"unknown kind {k!r}")
    if enveloped and "kind" in rec and rec["kind"] != k:
        raise SchemaError(f"kind mismatch: {rec['kind']!r} != {k!r}")
    for field, want in REQUIRED[k].items():
        if field not in rec:
            raise SchemaError(f"{k} record missing {field!r}")
        if want is not object and not isinstance(rec[field], want):
            raise SchemaError(
                f"{k}.{field} has type {type(rec[field]).__name__}")
    if k == "step" and "metrics" in rec:
        m = rec["metrics"]
        if not isinstance(m, dict) or not all(
                isinstance(n, str) and isinstance(v, _NUM)
                for n, v in m.items()):
            raise SchemaError("step.metrics must map str -> number")
    try:
        json.dumps(rec)
    except TypeError as e:
        raise SchemaError(f"{k} record not JSON-serializable: {e}") from e
    return k


def validate_jsonl(path: str) -> dict:
    """Validate every line of a JSONL sink file; returns per-kind counts.
    Raises SchemaError with the offending line number."""
    counts: dict[str, int] = {}
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SchemaError(f"{path}:{ln}: bad JSON: {e}") from e
            try:
                k = validate_record(rec)
            except SchemaError as e:
                raise SchemaError(f"{path}:{ln}: {e}") from e
            counts[k] = counts.get(k, 0) + 1
    return counts


def validate_bench_file(path: str) -> int:
    """Validate a ``BENCH_*.json`` artifact envelope + rows; returns the
    row count."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("bench"), str) \
            or not isinstance(doc.get("rows"), list):
        raise SchemaError(f"{path}: expected {{bench: str, rows: [...]}}")
    for i, row in enumerate(doc["rows"]):
        try:
            validate_record(row, kind="bench")
        except SchemaError as e:
            raise SchemaError(f"{path}: rows[{i}]: {e}") from e
    return len(doc["rows"])


# ----------------------------------------------------------------- manifest

def git_rev(root: str | None = None) -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or None
    except Exception:
        return None


def config_hash(cfg) -> str:
    """Stable short hash of any config-ish object (dataclass repr)."""
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:12]


def run_manifest(config=None, mesh=None, extra: dict | None = None) -> dict:
    """The run-header record: config hash + mesh shape + git rev (plus
    jax version and argv so a sink file is self-describing)."""
    try:
        import jax
        jax_version = jax.__version__
    except Exception:
        jax_version = None
    rec = {
        "config_hash": config_hash(config) if config is not None else "",
        "config": repr(config) if config is not None else None,
        "mesh": (dict(zip(mesh.axis_names,
                          (int(mesh.shape[a]) for a in mesh.axis_names)))
                 if hasattr(mesh, "axis_names") else mesh),
        "git_rev": git_rev(),
        "jax_version": jax_version,
        "argv": list(sys.argv),
    }
    if extra:
        rec.update(extra)
    return rec


# ------------------------------------------------------------------- writer

def _jsonable(value):
    """Host-convert scalars (jax/numpy arrays included) for JSON."""
    if isinstance(value, dict):
        return {k: _jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, bool) or value is None \
            or isinstance(value, (int, float, str)):
        return value
    if hasattr(value, "item"):        # 0-d jax/numpy array
        v = value.item()
        return float(v) if isinstance(v, float) else v
    if hasattr(value, "tolist"):
        return value.tolist()
    return str(value)


class MetricsWriter:
    """Schema-validated JSONL sink with an async flush thread.

    >>> with MetricsWriter(path, manifest=run_manifest(cfg, mesh)) as w:
    ...     w.write("step", step=0, loss=3.2, metrics=ms.host_floats())

    ``flush_every`` bounds the records buffered before an fsync-free
    file flush; close() drains the queue. ``append=True`` (the dry-run's
    resumable log) skips the manifest unless one is passed explicitly.

    Transient ``OSError`` during the drain (full disk, flaky NFS) is
    retried ``write_retries`` times with exponential backoff starting at
    ``retry_backoff_s``; a record that still fails is DROPPED and counted
    in ``self.dropped`` — a flaky sink degrades to a lossy one instead of
    silently killing the drain thread (close() warns, never raises, on
    drops). Non-OSError failures keep the old surface-on-close contract.
    """

    def __init__(self, path: str, manifest: dict | None = None,
                 flush_every: int = 20, append: bool = False,
                 write_retries: int = 3, retry_backoff_s: float = 0.05):
        self.path = path
        self.flush_every = max(1, int(flush_every))
        self.write_retries = max(0, int(write_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.dropped = 0
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._file = open(path, "a" if append else "w")
        self._queue: queue.Queue = queue.Queue()
        self._err: list = []
        self._thread = threading.Thread(target=self._drain, daemon=True)
        self._thread.start()
        self._closed = False
        if manifest is not None:
            self.write("manifest", **manifest)

    # -- producer side
    def write(self, kind: str, **fields) -> None:
        rec = {"schema": SCHEMA, "kind": kind}
        rec.update(_jsonable(fields))
        validate_record(rec, kind=kind)   # fail in the caller, not the thread
        self._queue.put(rec)

    def write_record(self, rec: dict) -> None:
        rec = dict(_jsonable(rec))
        rec.setdefault("schema", SCHEMA)
        rec.setdefault("kind", validate_record(rec))
        validate_record(rec)
        self._queue.put(rec)

    # -- consumer side
    def _write_one(self, rec: dict) -> bool:
        """One record with bounded retry on transient OSError; returns
        False when the record was dropped (retries exhausted)."""
        delay = self.retry_backoff_s
        for attempt in range(self.write_retries + 1):
            try:
                self._file.write(json.dumps(rec) + "\n")
                return True
            except OSError:
                if attempt == self.write_retries:
                    self.dropped += 1
                    return False
                time.sleep(delay)
                delay *= 2
        return False   # unreachable

    def _drain(self) -> None:
        pending = 0
        while True:
            rec = self._queue.get()
            if rec is None:
                break
            try:
                if self._write_one(rec):
                    pending += 1
                if pending >= self.flush_every or self._queue.empty():
                    try:
                        self._file.flush()
                    except OSError:
                        pass   # flush retries implicitly on next record
                    pending = 0
            except Exception as e:   # surface on close, never in-loop
                self._err.append(e)

    def flush(self) -> None:
        # barrier: wait until the drain thread has emptied the queue
        while not self._queue.empty():
            threading.Event().wait(0.005)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._queue.put(None)
        self._thread.join(timeout=10)
        try:
            self._file.flush()
        except OSError:
            pass
        self._file.close()
        if self.dropped:
            warnings.warn(
                f"MetricsWriter dropped {self.dropped} record(s) to "
                f"{self.path} after {self.write_retries} retries",
                RuntimeWarning, stacklevel=2)
        if self._err:
            raise self._err[0]

    def __enter__(self) -> "MetricsWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def write_bench_artifact(path: str, name: str, rows: list[dict],
                         fast: bool = False) -> None:
    """Write one ``BENCH_<name>.json`` envelope after validating every
    row against the bench schema — the benchmarks' shared exit point."""
    for i, row in enumerate(rows):
        try:
            validate_record(row, kind="bench")
        except SchemaError as e:
            raise SchemaError(f"{name}: rows[{i}]: {e}") from e
    with open(path, "w") as f:
        json.dump({"bench": name, "fast": bool(fast), "rows": rows}, f,
                  indent=2)
        f.write("\n")
