"""Production mesh construction (TPU v5e target).

  single pod : (16, 16)    -> ("data", "model")   256 chips
  multi pod  : (2, 16, 16) -> ("pod", "data", "model")  512 chips

Functions, not module-level constants, so importing this module never
touches jax device state. The dry-run process must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax
initialises its backend (dryrun.py's ``ensure_host_devices`` appends it
in ``main()``); real launches get the mesh from the slice topology.

Partition logic lives in ``repro.dist``; ``n_workers_for`` is re-exported
here for backwards compatibility with pre-dist callers.
"""
from __future__ import annotations

import jax

from repro.dist.sharding import n_workers_for  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"for the dry-run")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
