"""Static cost analyzer over compiled HLO text with *trip-count-aware*
loop accounting.

Why: ``compiled.cost_analysis()`` counts a while-loop body ONCE, but our
models scan over layers (and chunked attention scans over chunks), so the
built-in numbers undercount FLOPs, HBM bytes and collective bytes by the
trip count (verified: a scanned 8-layer matmul reports 1/8 the FLOPs of
the unrolled version). This module parses the per-device HLO module and
propagates per-computation costs through the call graph:

  total(comp) = own_cost(comp)
                + sum_fusion    boundary-bytes only (internals are fused)
                + sum_call      total(callee)
                + sum_while     trip_count * (total(body) + total(cond))

with
  * FLOPs: 2 * |output| * contracted-size for every dot (recursing into
    fused computations), |output| * dims for convolutions.
  * HBM bytes: operand + output bytes of every materialising top-level op
    (fusions count their boundary, which is exactly what XLA materialises).
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, kind-tagged.

Trip counts come from the loop condition: the largest integer literal in
a `compare(..., constant)` of the condition computation (exact for
lax.scan/fori_loop lowerings).

**Overlap accounting** (the staged wire pipeline, DESIGN.md §8): every
collective additionally yields a *pair record* with the compute FLOPs
the schedule lets it hide:

  * async ``<kind>-start`` / ``<kind>-done`` pairs (TPU/GPU text)
    attribute the FLOPs of the instructions *scheduled between* start
    and done — the overlap the backend actually emitted;
  * sync collectives (the CPU backend never splits them) attribute the
    FLOPs of instructions scheduled before the collective's first
    consumer that are neither ancestors nor descendants of it — the
    overlap a latency-hiding scheduler *could* realise by hoisting the
    issue to the operands-ready point (compiled HLO is scheduled:
    instruction order is the sequence the backend runs).

Pairs inside while bodies carry ``count = trip_count`` (bytes/FLOPs are
per occurrence). ``launch/hlo_analysis.py`` turns the pair list into the
``exposed_collective`` roofline term.

Validated against unrolled references in tests/test_hlo_cost.py.

The text-parsing layer lives in ``repro.analysis.hlo_ir`` (shared with
``hlo_analysis.py`` and the §12 lint rules); ``parse_module`` / ``Instr``
/ ``Computation`` are re-exported here unchanged.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

from repro.analysis.hlo_ir import (
    CALLED_RE as _CALLED,
    COLLECTIVES as _COLLECTIVES,
    COND_RE as _COND,
    Computation as Computation,
    Instr as Instr,
    entry_name as _entry_name,
    first_shape_dims as _first_shape_dims,
    parse_module as parse_module,
)

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "reshape", "iota", "partition-id",
             "replica-id", "convert"}
# "convert" is free: on TPU dtype converts fuse into producers/consumers
# (bf16 x bf16 -> f32 is native MXU); the CPU backend materialises them,
# which would otherwise leak CPU-only traffic into the roofline.


def _trip_count(cond: Computation) -> int:
    """Largest integer literal in the loop condition (exact for
    lax.scan / fori_loop: `lt(i, constant(N))`)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _while_trips(ins: Instr, comps: dict[str, Computation]) -> int:
    """Trip count of one while instruction: XLA's backend_config when
    present, else the loop-condition literal."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
    if m:
        return int(m.group(1))
    cond = _COND.search(ins.attrs)
    if cond:
        cc = comps.get(cond.group(1).lstrip("%"))
        if cc:
            return _trip_count(cc)
    return 1


def _conv_flops(ins: Instr, comp: Computation) -> float:
    # rough: 2 * |out| * (|rhs| / out_channels) — fine, convs are rare
    dims = _first_shape_dims(comp.types.get(ins.operands[1], ""))
    return 2.0 * comp.elems.get(ins.name, 0) * max(
        comp.elems.get(ins.operands[1], 1)
        // max(dims[-1:][0] if dims else 1, 1), 1)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = comp.elems.get(ins.name, 0)
    lhs_type = comp.types.get(ins.operands[0], "")
    dims = _first_shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs + ins.line)
    contracted = 1
    if m and dims:
        for d in m.group(1).split(","):
            if d and int(d) < len(dims):
                contracted *= dims[int(d)]
    return 2.0 * out_elems * contracted


# analysis.rules sizes candidate dots with the same model the cost
# propagation uses, so the replication audit and the roofline agree
dot_flops = _dot_flops


def _operand_read_bytes(comp: Computation, ins: Instr,
                        comps: dict[str, Computation]) -> float:
    """Bytes read by a fusion/op, with slice-aware accounting: a fusion
    parameter whose only in-fusion consumers are dynamic-slice/slice ops
    reads only the slice (scan bodies index loop-xs arrays this way — the
    whole stacked array must NOT be charged per trip)."""
    called = None
    m = _CALLED.search(ins.attrs)
    if m:
        called = comps.get(m.group(1).lstrip("%"))
    total = 0.0
    param_names: dict[int, str] = {}
    if called is not None:
        for fi in called.instrs:
            if fi.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", fi.line)
                if pm:
                    param_names[int(pm.group(1))] = fi.name
    for idx, opnd in enumerate(ins.operands):
        size = comp.sizes.get(opnd, 0)
        pname = param_names.get(idx)
        if called is not None and pname is not None and size > 0:
            consumers = [fi for fi in called.instrs if pname in fi.operands]
            if consumers and all(
                    fi.op.rstrip(".0123456789") in ("dynamic-slice", "slice")
                    for fi in consumers):
                size = sum(called.sizes.get(fi.name, 0) for fi in consumers)
        total += size
    return total


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    # one record per collective (pair accounting, module docstring):
    # {kind, bytes, u8, overlap_flops, count, name} — count scales with
    # the enclosing while trip counts, bytes/flops stay per occurrence;
    # name is the HLO instruction (for per-direction attribution and
    # debugging, see hlo_analysis.attribute_u8_directions).
    pairs: list = field(default_factory=list)
    # uint8 collective operands, tracked separately. With wire packing
    # on (the default) this is exactly the fused repro.wire payload
    # buffer — count 1, bytes == WireLayout.total_nbytes — comparable
    # to the analytic account. In the --no-wire-pack A/B arm it captures
    # only the uint8 payload leaves (Natural code/sign planes), NOT the
    # int32 index / bf16 value collectives, so it is a lower bound
    # there; use coll_by_kind for the unpacked arm's totals.
    u8_coll_bytes: float = 0.0
    u8_coll_count: float = 0.0

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += scale * other.flops
        self.hbm_bytes += scale * other.hbm_bytes
        self.coll_bytes += scale * other.coll_bytes
        self.u8_coll_bytes += scale * other.u8_coll_bytes
        self.u8_coll_count += scale * other.u8_coll_count
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + scale * v
        self.pairs.extend(dict(p, count=p["count"] * scale)
                          for p in other.pairs)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions
    (older versions return list[dict], newer a dict)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


_CALL_LIKE = ("call", "conditional", "map", "reduce", "reduce-window",
              "scatter", "select-and-scatter", "sort", "custom-call")


def _reach(comp: Computation, idx: int, pos: dict, users: dict,
           forward: bool) -> set[int]:
    """Instruction indices transitively reachable from ``idx`` —
    descendants (forward=True, via users) or ancestors (via operands)."""
    seen: set[int] = set()
    frontier = [idx]
    while frontier:
        i = frontier.pop()
        if forward:
            nxt = users.get(comp.instrs[i].name, [])
        else:
            nxt = [pos[o] for o in comp.instrs[i].operands if o in pos]
        for j in nxt:
            if j not in seen:
                seen.add(j)
                frontier.append(j)
    seen.discard(idx)
    return seen


def _pairs_for_comp(comp: Computation, instr_flops) -> list[dict]:
    """Pair records for one computation's collectives (module docstring):
    async start/done pairs use the scheduled window; sync collectives use
    the dependence-filtered prefix before their first consumer."""
    n = len(comp.instrs)
    fl = [instr_flops(ins) for ins in comp.instrs]
    prefix = [0.0]
    for v in fl:
        prefix.append(prefix[-1] + v)
    pos = {ins.name: i for i, ins in enumerate(comp.instrs)}
    users: dict[str, list[int]] = defaultdict(list)
    for i, ins in enumerate(comp.instrs):
        for o in ins.operands:
            if o in pos:
                users[o].append(i)
    pairs = []
    for i, ins in enumerate(comp.instrs):
        base = ins.op.rstrip(".0123456789")
        kind = next((k for k in _COLLECTIVES if base.startswith(k)), None)
        if kind is None or base.endswith("-done"):
            continue
        b = sum(comp.sizes.get(o, 0) for o in ins.operands)
        u8 = any(comp.types.get(o, "").startswith("u8[")
                 for o in ins.operands)
        orphan = False
        if base.endswith("-start"):
            # scheduled overlap: FLOPs strictly between start and done
            j = next((jx for jx in range(i + 1, n)
                      if comp.instrs[jx].op.rstrip(".0123456789")
                      == kind + "-done"
                      and ins.name in comp.instrs[jx].operands), None)
            if j is None:
                # no matching -done (truncated HLO text): the in-flight
                # window is unbounded, so the overlap credit is
                # meaningless. Mark the pair instead of silently
                # windowing to the end — attribute_u8_directions reports
                # orphans instead of matching them against a direction.
                j = n
                orphan = True
            flops = prefix[j] - prefix[i + 1]
        else:
            # sync collective: *schedulable* overlap — the FLOPs of every
            # instruction that neither feeds (ancestor) nor reads
            # (descendant) the collective. A latency-hiding scheduler is
            # free to keep such compute in flight between the issue
            # (operands ready) and the first consume; the sync schedule
            # the CPU backend emits carries no overlap information, so
            # the dependence cone is the honest static model. The
            # monolithic payload gather's cone covers the whole receive+
            # LMO phase (overlap ~0); each staged gather excludes only
            # its own stage's cone (DESIGN.md §8).
            anc = _reach(comp, i, pos, users, forward=False)
            desc = _reach(comp, i, pos, users, forward=True)
            flops = sum(fl[k] for k in range(n)
                        if k != i and k not in anc and k not in desc)
        p = {"kind": kind, "bytes": float(b), "u8": bool(u8),
             "overlap_flops": float(flops), "count": 1.0,
             "name": ins.name}
        if orphan:
            p["orphan"] = True
        pairs.append(p)
    return pairs


def analyze(text: str) -> dict:
    comps = parse_module(text)
    memo: dict[tuple[str, bool], Cost] = {}

    def instr_flops(comp: Computation, ins: Instr) -> float:
        """Trip-scaled FLOPs of ONE instruction (for the pair windows)."""
        base = ins.op.rstrip(".0123456789")
        if base in ("dot", "dot-general"):
            return _dot_flops(ins, comp)
        if base == "convolution":
            return _conv_flops(ins, comp)
        if base == "while":
            body = _CALLED.search(ins.attrs)
            if body:
                return _while_trips(ins, comps) * comp_cost(
                    body.group(1).lstrip("%"), True).flops
            return 0.0
        if base == "fusion" or base in _CALL_LIKE:
            return sum(comp_cost(t.lstrip("%"), True).flops
                       for t in _CALLED.findall(ins.attrs))
        return 0.0

    def comp_cost(name: str, fused: bool) -> Cost:
        """fused=True: inside a fusion — only FLOPs count (no HBM)."""
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        c = Cost()
        for ins in comp.instrs:
            base = ins.op.rstrip(".0123456789")
            if base in ("dot", "dot-general"):
                c.flops += _dot_flops(ins, comp)
                if not fused:
                    c.hbm_bytes += comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
            elif base == "convolution":
                c.flops += _conv_flops(ins, comp)
                if not fused:
                    c.hbm_bytes += comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
            elif any(base.startswith(k) for k in _COLLECTIVES):
                if base.endswith("-done"):
                    continue
                kind = next(k for k in _COLLECTIVES if base.startswith(k))
                b = sum(comp.sizes.get(o, 0) for o in ins.operands)
                c.coll_bytes += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + b
                u8 = sum(comp.sizes.get(o, 0) for o in ins.operands
                         if comp.types.get(o, "").startswith("u8["))
                if u8:
                    c.u8_coll_bytes += u8
                    c.u8_coll_count += 1
                if not fused:
                    c.hbm_bytes += b + comp.sizes.get(ins.name, 0)
            elif base == "fusion":
                called = _CALLED.search(ins.attrs)
                if called:
                    c.add(comp_cost(called.group(1).lstrip("%"), True))
                if not fused:
                    if "dynamic-update-slice" in ins.name:
                        # in-place update: traffic = written region only
                        szs = [comp.sizes.get(o, 0) for o in ins.operands
                               if comp.sizes.get(o, 0) > 0]
                        c.hbm_bytes += min(szs) if szs else 0
                    elif not ins.name.startswith(
                            ("wrapped_convert", "convert")):
                        # convert-rooted fusions are CPU artifacts (the CPU
                        # dot wants f32; TPU MXU takes bf16 directly)
                        c.hbm_bytes += comp.sizes.get(ins.name, 0) + \
                            _operand_read_bytes(comp, ins, comps)
            elif base == "while":
                body = _CALLED.search(ins.attrs)
                if body:
                    c.add(comp_cost(body.group(1).lstrip("%"), fused),
                          scale=float(_while_trips(ins, comps)))
            elif base in _CALL_LIKE:
                for target in _CALLED.findall(ins.attrs):
                    c.add(comp_cost(target.lstrip("%"), fused))
                if not fused and base != "call":
                    c.hbm_bytes += comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
            elif base in _FREE_OPS:
                continue
            elif base == "dynamic-update-slice":
                # in-place update (XLA aliases the buffer): traffic is the
                # written region, not the whole buffer.
                if not fused:
                    szs = [comp.sizes.get(o, 0) for o in ins.operands
                           if comp.sizes.get(o, 0) > 0]
                    c.hbm_bytes += min(szs) if szs else 0
            elif base in ("dynamic-slice", "slice"):
                # reads only the slice, not the sliced buffer
                if not fused:
                    c.hbm_bytes += 2 * comp.sizes.get(ins.name, 0)
            else:
                # materialising elementwise / data-movement op
                if not fused:
                    c.hbm_bytes += comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
        if not fused:
            c.pairs.extend(_pairs_for_comp(
                comp, lambda ins: instr_flops(comp, ins)))
        memo[key] = c
        return c

    entry = _entry_name(comps)
    c = comp_cost(entry, False)
    return {"flops": c.flops, "hbm_bytes": c.hbm_bytes,
            "coll_bytes": c.coll_bytes,
            "coll_by_kind": {k: int(v) for k, v in c.coll_by_kind.items()},
            "u8_coll_bytes": int(c.u8_coll_bytes),
            "u8_coll_count": int(c.u8_coll_count),
            "coll_pairs": [dict(p) for p in c.pairs],
            "entry": entry}


def top_contributors(text: str, n: int = 20, key: str = "hbm"):
    """Profile view for the perf loop: the n instructions contributing the
    most HBM bytes / FLOPs / collective bytes, trip-count-scaled."""
    comps = parse_module(text)
    entry = _entry_name(comps)
    rows: list[tuple[float, str, str, str]] = []

    def visit(name: str, scale: float, fused: bool):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            base = ins.op.rstrip(".0123456789")
            val = 0.0
            if base in ("dot", "dot-general"):
                if key == "flops":
                    val = _dot_flops(ins, comp)
                elif key == "hbm" and not fused:
                    val = comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
            elif any(base.startswith(k) for k in _COLLECTIVES):
                if key == "coll" and not base.endswith("-done"):
                    val = sum(comp.sizes.get(o, 0) for o in ins.operands)
            elif base == "while":
                body = _CALLED.search(ins.attrs)
                m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
                trips = int(m.group(1)) if m else 1
                if body:
                    visit(body.group(1).lstrip("%"), scale * trips, fused)
                continue
            elif base == "fusion":
                called = _CALLED.search(ins.attrs)
                if called and key == "flops":
                    visit(called.group(1).lstrip("%"), scale, True)
                if key == "hbm" and not fused and not ins.name.startswith(
                        ("wrapped_convert", "convert")):
                    if "dynamic-update-slice" in ins.name:
                        szs = [comp.sizes.get(o, 0) for o in ins.operands
                               if comp.sizes.get(o, 0) > 0]
                        val = min(szs) if szs else 0
                    else:
                        val = comp.sizes.get(ins.name, 0) + \
                            _operand_read_bytes(comp, ins, comps)
            elif base in _FREE_OPS or fused:
                continue
            elif key == "hbm":
                if base == "dynamic-update-slice":
                    szs = [comp.sizes.get(o, 0) for o in ins.operands
                           if comp.sizes.get(o, 0) > 0]
                    val = min(szs) if szs else 0
                elif base in ("dynamic-slice", "slice"):
                    val = 2 * comp.sizes.get(ins.name, 0)
                else:
                    val = comp.sizes.get(ins.name, 0) + sum(
                        comp.sizes.get(o, 0) for o in ins.operands)
            if val:
                rows.append((val * scale, name, ins.op, ins.name))

    visit(entry, 1.0, False)
    rows.sort(reverse=True)
    return rows[:n]
