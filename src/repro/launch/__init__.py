# Launch layer: production mesh, multi-pod dry-run, train/serve CLIs.
# Import modules directly (repro.launch.mesh / .dryrun / .train / .serve);
# importing dryrun is side-effect free — its main() sets XLA_FLAGS
# (appending to any existing value) before the first device query.
