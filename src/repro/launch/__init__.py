# Launch layer: production mesh, multi-pod dry-run, train/serve CLIs.
# Import modules directly (repro.launch.mesh / .dryrun / .train / .serve);
# dryrun must be the FIRST import in its process (it sets XLA_FLAGS).
