"""Serving CLI: batched prefill + decode on a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.models.api import build_model, make_batch
from repro.train.serve import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(args.seed))
    server = Server(model)
    batch = make_batch(cfg, ShapeSpec("p", "prefill", args.prompt_len,
                                      args.batch), jax.random.key(1))
    t0 = time.time()
    toks = server.generate(params, batch, args.max_new,
                           temperature=args.temperature,
                           key=jax.random.key(2))
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new} wall={dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    for row in toks[: min(4, toks.shape[0])]:
        print("  ", " ".join(str(int(t)) for t in row))


if __name__ == "__main__":
    main()
