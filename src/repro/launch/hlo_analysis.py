"""Collective-byte accounting from lowered/compiled HLO text.

``cost_analysis()`` has no collective term, so we parse the (SPMD
partitioned, per-device) HLO module: build a table of instruction output
sizes, then for every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute) sum the byte sizes of
its *operands* — the data each device puts on the wire.

This is per-device program text, so the sums are bytes-per-device per
step, which is what the roofline collective term wants.
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.analysis.hlo_ir import collective_kind, operand_span, type_bytes

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+) = (.*?) ([\w\-]+)\(")


def collective_bytes(hlo_text: str) -> dict:
    """Returns {'total': bytes, 'by_kind': {kind: bytes}, 'count': int}."""
    sizes: dict[str, int] = {}
    pending: list[tuple[str, str]] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        sizes[name.lstrip("%")] = type_bytes(type_str)
        kind, phase = collective_kind(op)
        if kind is None or phase == "done":   # bytes counted at -start
            continue
        span, _ = operand_span(line[line.index("(") + 1:])
        ops = [a.strip().lstrip("%") for a in span.split(",") if a.strip()]
        pending.append((kind, ",".join(ops)))
    by_kind: dict[str, int] = defaultdict(int)
    count = 0
    for coll, ops in pending:
        b = sum(sizes.get(o, 0) for o in ops.split(",") if o)
        by_kind[coll] += b
        count += 1
    return {"total": int(sum(by_kind.values())),
            "by_kind": dict(by_kind), "count": count}


def attribute_u8_directions(coll_pairs: list, w2s_sizes, s2w_sizes) -> dict:
    """Attribute uint8 collective pair records to wire directions
    (DESIGN.md §9) by byte-matching against the two directions' static
    stage sub-buffer sizes.

    Both wire legs lower to u8 all-gathers whose per-device operand
    bytes equal their stage sub-buffer exactly (the byte-for-byte
    invariant), so the multiset of expected sizes identifies each
    collective: ``w2s_sizes`` / ``s2w_sizes`` are the per-stage byte
    counts (one entry per expected collective; repeat entries for
    repeated sizes). A byte count both directions expect is resolved by
    remaining quota — each expected entry is consumed at most once, so
    counts stay exact even on collisions. Returns per-direction
    measured ``{"bytes", "count"}`` plus ``unmatched_bytes`` (u8 pairs
    no direction expected) and ``missing`` (expected sizes never seen)
    — both empty iff the two-direction invariant holds.

    A pair flagged ``orphan`` (an async ``-start`` whose ``-done`` never
    appeared — truncated HLO text, see hlo_cost) is **not** matched
    against either direction: a gather that cannot be shown to complete
    must not satisfy the byte invariant. Its bytes are reported under
    ``missing["orphan"]`` (and its expected size, if any, stays missing
    too), so truncation surfaces as a violation instead of silently
    passing partial attribution."""
    expected = {"w2s": defaultdict(int), "s2w": defaultdict(int)}
    for s in w2s_sizes:
        expected["w2s"][int(s)] += 1
    for s in s2w_sizes:
        expected["s2w"][int(s)] += 1
    out = {d: {"bytes": 0, "count": 0} for d in ("w2s", "s2w")}
    unmatched: list[int] = []
    orphans: list[int] = []
    for p in coll_pairs:
        if not p.get("u8"):
            continue
        b = int(p["bytes"])
        n = max(int(round(p.get("count", 1.0))), 0)
        if p.get("orphan"):
            orphans.extend([b] * n)
            continue
        for _ in range(n):
            d = next((d for d in ("w2s", "s2w") if expected[d][b] > 0),
                     None)
            if d is None:
                unmatched.append(b)
            else:
                expected[d][b] -= 1
                out[d]["bytes"] += b
                out[d]["count"] += 1
    missing = {d: sorted(sz for sz, n in exp.items() for _ in range(n))
               for d, exp in expected.items() if sum(exp.values())}
    if orphans:
        missing["orphan"] = sorted(orphans)
    return {"w2s": out["w2s"], "s2w": out["s2w"],
            "unmatched_bytes": sorted(unmatched), "missing": missing}


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float, *, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, ici_bw: float = 50e9) -> dict:
    """Three-term roofline in seconds, per device (TPU v5e constants:
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI)."""
    t_c = flops / peak_flops
    t_m = bytes_accessed / hbm_bw
    t_x = coll_bytes / ici_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {"t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom}


def exposed_collective_terms(coll_pairs: list, coll_bytes: float, *,
                             peak_flops: float = 197e12,
                             ici_bw: float = 50e9) -> dict:
    """Overlap-aware collective term (DESIGN.md §8): the plain roofline
    charges ``coll_bytes / ici_bw`` as if every byte serialises ahead of
    all compute, but the staged wire pipeline gives the scheduler K
    independent gathers whose latency hides under the Newton-Schulz
    compute of earlier stages. ``coll_pairs`` is the pair list from
    ``hlo_cost.analyze`` ({kind, bytes, overlap_flops, count} per
    collective, counts trip-scaled); per pair the *exposed* time is the
    collective time minus the compute scheduled (or schedulable — see
    hlo_cost's sync-collective model) inside its in-flight window,
    floored at zero. Unpaired bytes (coll_bytes beyond the pair sum)
    stay fully exposed.

    Deliberately per-pair, as §8 defines it: the same independent
    compute may be credited to several collectives' windows (all K
    staged gathers are in flight together, so per gather this is what a
    perfect latency-hiding schedule could achieve — but the aggregate
    is a lower bound on exposure, not additive wall-time). Read it as
    an A/B ratio between arms of the same program, where the shared
    credit cancels, rather than as an absolute seconds figure."""
    paired = sum(p["count"] * p["bytes"] for p in coll_pairs)
    exposed = sum(p["count"] * max(0.0, p["bytes"] / ici_bw
                                   - p["overlap_flops"] / peak_flops)
                  for p in coll_pairs)
    exposed += max(0.0, coll_bytes - paired) / ici_bw
    t_x = coll_bytes / ici_bw
    return {"t_exposed_collective_s": exposed,
            "paired_coll_bytes": int(paired),
            "hidden_collective_frac": (1.0 - exposed / t_x) if t_x else 0.0}


def overlap_roofline_terms(flops: float, bytes_accessed: float,
                           coll_bytes: float, coll_pairs: list, *,
                           peak_flops: float = 197e12,
                           hbm_bw: float = 819e9,
                           ici_bw: float = 50e9) -> dict:
    """``roofline_terms`` plus the exposed-collective term, with the
    bottleneck recomputed against the *exposed* (not total) collective
    time — the sum-of-terms assumption replaced by measured overlap."""
    terms = roofline_terms(flops, bytes_accessed, coll_bytes,
                           peak_flops=peak_flops, hbm_bw=hbm_bw,
                           ici_bw=ici_bw)
    terms.update(exposed_collective_terms(coll_pairs, coll_bytes,
                                          peak_flops=peak_flops,
                                          ici_bw=ici_bw))
    dom = max((terms["t_compute_s"], "compute"),
              (terms["t_memory_s"], "memory"),
              (terms["t_exposed_collective_s"], "collective"))[1]
    terms["bottleneck_overlap"] = dom
    return terms
