"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination this lowers the
real entry point — the EF21-Muon ``train_step`` for train shapes,
``prefill`` / ``decode_step`` for serving shapes — against
ShapeDtypeStruct inputs (no allocation), compiles it for the production
mesh, and records:

  * memory_analysis()            (per-device bytes: proves it fits)
  * cost_analysis()              (per-device HLO FLOPs / bytes accessed)
  * collective bytes             (parsed from the compiled HLO module)
  * three-term roofline + bottleneck (launch/hlo_analysis.py)

Results are appended to results/dryrun.jsonl (idempotent by
(arch, shape, mesh, tag) key) — the roofline report and EXPERIMENTS.md
read from there.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import math
import os
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.dist import (batch_pspec, n_workers_for, param_pspecs,
                        serve_pspecs, to_shardings)
from repro.launch.hlo_analysis import (attribute_u8_directions,
                                       overlap_roofline_terms)
from repro.launch.hlo_cost import analyze, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.models.api import abstract_params as _abstract_params
from repro.models.api import build_model, input_specs
from repro.obs.sink import MetricsWriter
from repro.train.faults import parse_faults
from repro.train.trainer import Trainer, TrainerConfig

RESULTS = os.path.join(os.path.dirname(__file__), "../../..",
                       "results/dryrun.jsonl")
RESULTS = os.path.abspath(RESULTS)

FSDP_THRESHOLD = 8e9   # params above this get FSDP over the data axis


def ensure_host_devices(n: int = 512) -> None:
    """Request ``n`` emulated host CPU devices for the production-mesh
    dry-run. Respects an existing ``XLA_FLAGS`` value: appends instead of
    overwriting, and defers to any device-count flag already present
    (e.g. the 8-device SPMD test subprocesses). Called from ``main()``
    only — importing this module (tests import ``lower_pair``) never
    mutates the environment. Must run before jax initialises its
    backend; a too-late call is caught by ``make_production_mesh``'s
    device-count check."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in (flags, f"--xla_force_host_platform_device_count={n}")
        if f)


def _param_counts(cfg, shapes, metas):
    treedef = jax.tree.structure(shapes)
    metas_l = treedef.flatten_up_to(metas)
    total = active = 0
    for p, m in zip(jax.tree.leaves(shapes), metas_l):
        n = math.prod(p.shape)
        total += n
        if m.stack_dims >= 2 and cfg.moe:   # routed expert stack [L, E, ...]
            active += n * (cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return int(total), int(active)


def _model_flops(cfg, shape, total, active):
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * active * tokens
    return 2.0 * active * tokens


def skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full attention (no sliding-window/recurrent state): "
                "sub-quadratic requirement not met; documented in DESIGN.md")
    return None


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               w2s: str = "rank10", tag: str = "baseline",
               fsdp: bool | None = None, beta: float = 0.1,
               s2w: str = "identity", pad_heads: int | None = None,
               zero1_lmo: bool = False, wire_pack: bool = True,
               ns_bucketing: bool = True, wire_stages="auto",
               wire_pack_s2w="auto", participation="full",
               faults: str | None = None, resync: int = 0):
    """Lower + compile one (arch, shape, mesh). Returns the record dict."""
    import dataclasses
    cfg = get_config(arch)
    if pad_heads:
        # TP adaptation (§Perf C2): pad q-heads up to a multiple of the
        # model axis — kills the head_dim-split score all-reduces.
        cfg = dataclasses.replace(cfg, n_heads=pad_heads, head_dim=cfg.hd)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "w2s": w2s}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.devices.shape)
    model = build_model(cfg)
    pshapes, metas = _abstract_params(model)
    total, active = _param_counts(cfg, pshapes, metas)
    use_fsdp = (total > FSDP_THRESHOLD) if fsdp is None else fsdp
    rec.update(n_devices=n_dev, params=total, params_active=active,
               fsdp=use_fsdp)

    t0 = time.time()
    w2s_stage_sizes: list = []
    s2w_stage_sizes: list = []
    if shape.kind == "train":
        n_w = n_workers_for(mesh)
        fplan = (parse_faults(faults, n_w) if faults else None)
        tr = Trainer(model, TrainerConfig(
            n_workers=n_w, beta=beta, w2s=w2s, s2w=s2w, fsdp=use_fsdp,
            use_pallas=False, zero1_lmo=zero1_lmo,
            wire_pack=wire_pack, ns_bucketing=ns_bucketing,
            wire_stages=wire_stages, wire_pack_s2w=wire_pack_s2w,
            participation=participation, faults=fplan,
            resync=resync),
            mesh=mesh)
        if participation != "full" or fplan is not None:
            # the elastic/chaos dry-run arm: prove the masked fold +
            # guard lower and compile at production scale
            rec.update(participation=str(participation),
                       faults=faults or "")
        if resync:
            # the §13 rejoin arm: prove the replay ring + per-worker W
            # estimates lower and compile at production scale
            rec.update(resync=int(resync))
        # wire accounting: analytic Table-2 bytes vs the exact bytes the
        # fused payload buffer moves (compare with the measured
        # u8_coll_bytes parsed from the compiled HLO below; that
        # comparison is only meaningful when wire_pack is on — in the
        # --no-wire-pack arm u8_coll_bytes sees just the uint8 payload
        # leaves, a lower bound on the unpacked payload traffic)
        plan = tr.layer_plan()
        wire_dt = tr.opt.cfg.wire_dtype
        # s2w leg (§9): analytic + exact wire bytes of the model-update
        # broadcast. The resolved pack switches and the expected
        # per-collective stage sizes come from the shared WireBudget
        # (core.muon) — the exact resolution the compiled step uses, so
        # the attribution below can never drift from the lowering.
        budget = tr.wire_budget()
        s2w_analytic = (plan.s2w_bytes_per_round(wire_dt)
                        if s2w != "identity" else 0)
        s2w_wire = budget.s2w_nbytes
        w2s_stage_sizes = list(budget.w2s_sizes)
        s2w_stage_sizes = list(budget.s2w_sizes)
        w2s_analytic = plan.w2s_bytes_per_worker(wire_dt)
        w2s_wire = plan.wire_layout(wire_dt).total_nbytes
        rec.update(w2s_bytes_analytic=w2s_analytic,
                   w2s_bytes_wire=w2s_wire,
                   s2w_bytes_analytic=s2w_analytic,
                   wire_bytes_s2w=s2w_wire,
                   wire_pack=wire_pack, wire_pack_s2w=wire_pack_s2w,
                   two_way_bytes_analytic=w2s_analytic + s2w_analytic,
                   two_way_bytes_wire=w2s_wire + s2w_wire,
                   ns_bucketing=ns_bucketing,
                   # the mesh-aware bucket count — what the compiled step
                   # actually dispatches (TP-orientation sub-splits
                   # included), not the mesh-less grouping
                   ns_buckets=len(plan.ns_buckets(mesh=mesh,
                                                  fsdp=use_fsdp)),
                   wire_stages=wire_stages,
                   # effective pipeline stage count (§8); 1 when the
                   # staged path collapses to the monolithic gather
                   n_wire_stages=budget.n_stages)
        batch = input_specs(cfg, shape, n_workers=n_w)
        state = tr.state_shapes()
        jitted = tr.jit_step(batch)
        lowered = jitted.lower(state, batch,
                               jax.ShapeDtypeStruct((), jnp.float32))
    else:
        p_sh = to_shardings(param_pspecs(pshapes, metas, mesh,
                                         fsdp=use_fsdp), mesh)
        cache = model.cache_spec(shape.batch, shape.seq)
        c_sh = to_shardings(
            serve_pspecs(cache, shape.batch, mesh,
                         cache_alt=model.cache_spec(shape.batch + 1,
                                                    shape.seq)), mesh)
        batch = input_specs(cfg, shape)
        b_sh = to_shardings(batch_pspec(batch, mesh, shape.kind), mesh)
        fn = model.prefill if shape.kind == "prefill" else model.decode_step
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh))
        lowered = jitted.lower(pshapes, batch, cache)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    # primary costs: trip-count-aware static analyzer (XLA cost_analysis
    # counts while bodies once — see hlo_cost.py docstring)
    cost = analyze(hlo_text)
    flops = float(cost["flops"])
    bytes_acc = float(cost["hbm_bytes"])
    xla_cost = cost_analysis_dict(compiled)
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": int(ma.argument_size_in_bytes),
               "output_bytes": int(ma.output_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes),
               "peak_bytes": int(ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes)}
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)[:200]}
    mflops = _model_flops(cfg, shape, total, active)
    # overlap-aware roofline (§8): collective bottleneck term computed
    # from per-pair exposed time, not the serialise-everything sum
    terms = overlap_roofline_terms(flops, bytes_acc, cost["coll_bytes"],
                                   cost["coll_pairs"])
    u8_pairs = [p for p in cost["coll_pairs"] if p["u8"]]
    if w2s_stage_sizes or s2w_stage_sizes:
        # per-direction u8 attribution (§9): the wire collectives are
        # the u8 all-gathers — every one must match an expected stage
        # sub-buffer size, so the measured two-way split is exact
        # whenever unmatched/missing are empty. Non-gather u8 traffic
        # (the partitioner's masked-DUS + all-reduce assembly of the
        # TP-sharded s2w pack buffer, see tests/test_sharding.py) is
        # reported separately as repack bytes.
        split = attribute_u8_directions(
            [p for p in u8_pairs if p["kind"] == "all-gather"],
            w2s_stage_sizes, s2w_stage_sizes)
        rec.update(
            u8_bytes_w2s=split["w2s"]["bytes"],
            u8_count_w2s=split["w2s"]["count"],
            u8_bytes_s2w=split["s2w"]["bytes"],
            u8_count_s2w=split["s2w"]["count"],
            u8_unmatched_bytes=sum(split["unmatched_bytes"]),
            u8_missing=split["missing"],
            u8_repack_bytes=int(sum(p["count"] * p["bytes"]
                                    for p in u8_pairs
                                    if p["kind"] != "all-gather")),
            two_way_bytes_measured=(split["w2s"]["bytes"]
                                    + split["s2w"]["bytes"]))
    rec.update(
        u8_pair_overlap_flops=sum(p["count"] * p["overlap_flops"]
                                  for p in u8_pairs),
        # per payload-gather pair: [bytes, hideable FLOPs] (§8 evidence)
        u8_pairs=[[int(p["bytes"]), int(p["count"] * p["overlap_flops"])]
                  for p in u8_pairs],
        coll_pair_count=round(sum(p["count"]
                                  for p in cost["coll_pairs"]), 2),
        status="ok", t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        hlo_flops=flops, flops_per_device=flops, hlo_bytes=bytes_acc,
        coll_bytes=int(cost["coll_bytes"]),
        coll_by_kind=cost["coll_by_kind"],
        u8_coll_bytes=cost["u8_coll_bytes"],
        u8_coll_count=cost["u8_coll_count"],
        xla_flops=float(xla_cost.get("flops", 0.0)),
        xla_bytes=float(xla_cost.get("bytes accessed", 0.0)),
        model_flops=mflops, model_flops_per_dev=mflops / n_dev,
        useful_flops_ratio=(mflops / n_dev) / flops if flops else None,
        memory=mem, **terms)
    return rec


def ns_ab_pair(arch: str, shape_name: str, multi_pod: bool,
               tag: str = "nsab", **kw) -> tuple[dict, dict]:
    """Lower + compile one (arch, shape, mesh) with NS bucketing on AND
    off, and record the per-arm ``flops_per_device`` plus the
    ``ns_flops_ratio`` (bucketed / per-leaf) on the bucketed record — the
    number the sharding-aware bucketing keeps at <= 1.02x (was 1.137x
    when the bucket concat replicated the NS chain)."""
    on = lower_pair(arch, shape_name, multi_pod, tag=f"{tag}-on",
                    ns_bucketing=True, **kw)
    off = lower_pair(arch, shape_name, multi_pod, tag=f"{tag}-off",
                     ns_bucketing=False, **kw)
    if on.get("status") == "ok" and off.get("status") == "ok" \
            and off.get("flops_per_device"):
        ratio = on["flops_per_device"] / off["flops_per_device"]
        on["ns_flops_ratio"] = round(ratio, 4)
    return on, off


def pipeline_ab_pair(arch: str, shape_name: str, multi_pod: bool,
                     tag: str = "pipeab", wire_stages="auto",
                     **kw) -> tuple[dict, dict]:
    """Lower + compile one (arch, shape, mesh) with the staged wire
    pipeline on (``wire_stages`` staged arm) AND off (``wire_stages=1``,
    the monolithic single-gather arm, bit-identical to the PR-4 step) and
    record the ``exposed_collective_ratio`` (staged / monolithic
    ``t_exposed_collective_s``) on the staged record — the §8 acceptance
    number: strictly < 1 when the K-gather schedule hides latency the
    monolithic gather serialises."""
    staged = lower_pair(arch, shape_name, multi_pod, tag=f"{tag}-staged",
                        wire_stages=wire_stages, **kw)
    mono = lower_pair(arch, shape_name, multi_pod, tag=f"{tag}-mono",
                      wire_stages=1, **kw)
    if staged.get("status") == "ok" and mono.get("status") == "ok" \
            and mono.get("t_exposed_collective_s"):
        staged["exposed_collective_ratio"] = round(
            staged["t_exposed_collective_s"]
            / mono["t_exposed_collective_s"], 4)
    return staged, mono


# --------------------------------------------------------------------- CLI

def _load_done(path: str) -> set:
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r["tag"]))
                except Exception:
                    pass
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--w2s", default="rank10")
    ap.add_argument("--s2w", default="identity")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--pad-heads", type=int, default=None,
                    help="pad q-heads to this count (TP adaptation, C2)")
    ap.add_argument("--zero1", action="store_true",
                    help="beyond-paper layer-parallel LMO sharding")
    ap.add_argument("--no-wire-pack", action="store_true",
                    help="ship the unpacked payload pytree (per-leaf "
                         "collectives) instead of the fused wire buffer")
    ap.add_argument("--no-wire-pack-s2w", action="store_true",
                    help="keep the unpacked EF21-P phase-1 path (the "
                         "value-bit-equal A/B arm) instead of the s2w "
                         "wire broadcast (§9)")
    ap.add_argument("--no-ns-bucketing", action="store_true",
                    help="per-leaf Newton-Schulz chains instead of the "
                         "shape-bucketed batched dispatch (DESIGN.md §7)")
    ap.add_argument("--ns-ab", action="store_true",
                    help="compile each combination with NS bucketing on "
                         "AND off and record ns_flops_ratio (per-device "
                         "HLO FLOPs, bucketed / per-leaf)")
    ap.add_argument("--wire-stages", default="auto",
                    help="staged wire pipeline stage cap (§8): 'auto' = "
                         "one stage per NS bucket + the eager chunk, 1 = "
                         "the monolithic single-gather arm, N caps the "
                         "count by merging the smallest-FLOP buckets")
    ap.add_argument("--pipeline-ab", action="store_true",
                    help="compile each combination with the staged wire "
                         "pipeline on AND off (wire_stages=1) and record "
                         "exposed_collective_ratio (overlap-aware "
                         "roofline, staged / monolithic)")
    ap.add_argument("--participation", default="full", metavar="SPEC",
                    help="elastic worker participation (§11): 'full', "
                         "'bernoulli(p)' or 'round_robin(k)' — proves "
                         "the masked fold compiles at production scale")
    ap.add_argument("--resync", type=int, default=0, metavar="R",
                    help="desynchronized-worker rejoin (§13): R-deep "
                         "replay ring + per-worker W estimates; needs "
                         "a compressing --s2w")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="chaos schedule compiled into the step "
                         "(repro.train.faults grammar)")
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    ensure_host_devices(512)

    archs = [a for a in ARCHS if a != "nanogpt-124m"] if args.all \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    fsdp = None if args.fsdp is None else (args.fsdp == "on")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    done = set() if args.force else _load_done(args.out)
    wire_stages = args.wire_stages if args.wire_stages == "auto" \
        else int(args.wire_stages)
    # append-mode sink: the resumable dry-run log keeps its history (the
    # validator accepts both legacy rows and v1-enveloped ones), new rows
    # are schema-stamped kind="dryrun" and flushed per combination
    writer = MetricsWriter(args.out, append=True, flush_every=1)
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = args.tag
                if args.ns_ab:
                    tag, resume_sfx = f"{tag}-nsab", "-on"
                elif args.pipeline_ab:
                    tag, resume_sfx = f"{tag}-pipeab", "-staged"
                else:
                    resume_sfx = ""
                key = (arch, shape, mesh, f"{tag}{resume_sfx}")
                if key in done:
                    print(f"[skip-done] {key}", flush=True)
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh} "
                      f"(w2s={args.w2s}, tag={tag})", flush=True)
                kw = dict(w2s=args.w2s, fsdp=fsdp, s2w=args.s2w,
                          pad_heads=args.pad_heads, zero1_lmo=args.zero1,
                          wire_pack=not args.no_wire_pack,
                          wire_pack_s2w=(False if args.no_wire_pack_s2w
                                         else "auto"),
                          participation=args.participation,
                          faults=args.faults, resync=args.resync)
                try:
                    if args.ns_ab:
                        recs = list(ns_ab_pair(arch, shape, mesh == "multi",
                                               tag=tag,
                                               wire_stages=wire_stages,
                                               **kw))
                    elif args.pipeline_ab:
                        recs = list(pipeline_ab_pair(
                            arch, shape, mesh == "multi", tag=tag,
                            wire_stages=("auto" if wire_stages == 1
                                         else wire_stages),
                            ns_bucketing=not args.no_ns_bucketing, **kw))
                    else:
                        recs = [lower_pair(
                            arch, shape, mesh == "multi", tag=tag,
                            ns_bucketing=not args.no_ns_bucketing,
                            wire_stages=wire_stages, **kw)]
                except Exception as e:
                    # in A/B modes the resume key is the -on/-staged tag;
                    # the error record must carry it or resumes
                    # re-compile every errored combo
                    recs = [{"arch": arch, "shape": shape, "mesh": mesh,
                             "tag": f"{tag}{resume_sfx}",
                             "status": "error",
                             "error": f"{type(e).__name__}: {e}"[:500],
                             "trace": traceback.format_exc()[-2000:]}]
                for rec in recs:
                    writer.write_record({"kind": "dryrun", **rec})
                writer.flush()
                for rec in recs:
                    brief = {k: rec.get(k) for k in
                             ("tag", "status", "t_compile_s", "hlo_flops",
                              "coll_bytes", "bottleneck",
                              "bottleneck_overlap",
                              "t_exposed_collective_s", "n_wire_stages",
                              "ns_flops_ratio", "exposed_collective_ratio",
                              "reason", "error")}
                    if rec.get("status") == "ok" \
                            and "w2s_bytes_wire" in rec:
                        # both wire directions + the two-way total (§9)
                        brief.update({k: rec.get(k) for k in
                                      ("w2s_bytes_wire", "wire_bytes_s2w",
                                       "two_way_bytes_wire",
                                       "two_way_bytes_measured")})
                    print(f"   -> {brief}", flush=True)
    writer.close()


if __name__ == "__main__":
    main()
