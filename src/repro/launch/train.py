"""Training CLI (CPU-scale real runs; the dry-run exercises full scale).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50 --workers 2 --w2s top10 --radius 0.01

Runs the distributed EF21-Muon trainer on the synthetic Zipf-Markov
pipeline, logs loss + w2s wire bytes, and optionally checkpoints.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.schedule import warmup_linear_decay
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.obs.sink import MetricsWriter, run_manifest
from repro.obs.trace import span_summary
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.faults import parse_faults
from repro.train.supervisor import Supervisor, SupervisorConfig
from repro.train.trainer import Trainer, TrainerConfig


def _ef_summary_rows(metrics: dict, limit: int = 12) -> list[dict]:
    """Per-layer EF21 error rows from one step's metric dict, largest
    ``ef/err_norm`` first (the layers where compression bites hardest)."""
    rows = []
    for name, v in metrics.items():
        if not name.startswith("ef/err_norm/"):
            continue
        leaf = name[len("ef/err_norm/"):]
        rows.append({
            "leaf": leaf, "err_norm": v,
            "rel_err": metrics.get(f"ef/rel_err/{leaf}"),
            "momentum_norm": metrics.get(f"ef/momentum_norm/{leaf}"),
        })
    rows.sort(key=lambda r: -(r["err_norm"] or 0.0))
    return rows[:limit]


def _print_tables(spans: list[dict], ef_rows: list[dict]) -> None:
    if spans:
        print("-- host phase timings --")
        print(f"{'span':32s} {'count':>6s} {'total_s':>9s} {'max_s':>9s}")
        for r in spans:
            print(f"{r['name']:32s} {r['count']:6d} "
                  f"{r['total_s']:9.4f} {r['max_s']:9.4f}")
    if ef_rows:
        print("-- EF21 error by layer (final step, worst first) --")
        print(f"{'leaf':28s} {'err_norm':>10s} {'rel_err':>8s} "
              f"{'momentum':>10s}")
        for r in ef_rows:
            rel = r["rel_err"]
            mom = r["momentum_norm"]
            print(f"{r['leaf']:28s} {r['err_norm']:10.4g} "
                  f"{(f'{rel:8.3f}' if rel is not None else '       -')} "
                  f"{(f'{mom:10.4g}' if mom is not None else '         -')}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--w2s", default="top10")
    ap.add_argument("--s2w", default="identity")
    ap.add_argument("--radius", type=float, default=0.01)
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write schema-versioned JSONL metrics here "
                         "(implies in-graph metrics collection, §10)")
    ap.add_argument("--trace-spans", action="store_true",
                    help="named-scope the step phases for xprof captures")
    ap.add_argument("--participation", default="full", metavar="SPEC",
                    help="elastic worker participation (§11): 'full', "
                         "'bernoulli(p)' or 'round_robin(k)'")
    ap.add_argument("--participation-seed", type=int, default=0)
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="chaos schedule, e.g. "
                         "'drop:w=1:steps=5-10,nan:w=0:steps=7,"
                         "flip:steps=4:bits=8' (repro.train.faults)")
    ap.add_argument("--donate", action="store_true",
                    help="donate the optimizer state to the jitted step "
                         "(in-place buffer reuse instead of double-"
                         "buffering; the §12 donation-audit rule "
                         "certifies the aliasing)")
    ap.add_argument("--resync", type=int, default=0, metavar="R",
                    help="desynchronized-worker rejoin (§13): keep "
                         "per-worker W estimates + an R-deep replay ring "
                         "of packed s2w rounds; 0 compiles it out. "
                         "Requires a compressing --s2w")
    ap.add_argument("--supervise", action="store_true",
                    help="run the loop under the §13 supervisor "
                         "(per-step timeout, bounded retry, checkpoint-"
                         "reload recovery)")
    ap.add_argument("--step-timeout", type=float, default=None,
                    metavar="SEC", help="supervisor per-step watchdog")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="supervisor re-dispatches per step")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    metavar="N", help="periodic last-good checkpoint to "
                         "--checkpoint every N steps (supervisor "
                         "recovery granularity)")
    args = ap.parse_args()
    if args.supervise and args.donate:
        print("warning: --supervise needs the input state intact for "
              "retries; disabling --donate")
        args.donate = False

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    data = SyntheticLM(cfg, shape, n_workers=args.workers, seed=args.seed)
    faults = (parse_faults(args.faults, args.workers, seed=args.seed)
              if args.faults else None)
    tcfg = TrainerConfig(
        n_workers=args.workers, beta=args.beta, w2s=args.w2s, s2w=args.s2w,
        remat=False, use_pallas=False, metrics=args.metrics_out is not None,
        trace_spans=args.trace_spans, participation=args.participation,
        participation_seed=args.participation_seed, faults=faults,
        donate=args.donate, resync=args.resync)
    tr = Trainer(model, tcfg)
    state = tr.init(jax.random.key(args.seed))
    start = 0
    if args.resume:
        state, start = load_checkpoint(args.resume, state)
        print(f"resumed from {args.resume} @ step {start}")
    # jit through the trainer so --donate's donate_argnums applies (the
    # input state is consumed per step; the loop rebinds it anyway)
    step_fn = tr.jit_step(None)
    sched = warmup_linear_decay(args.radius, args.warmup, args.steps)
    # wire accounting straight from the LayerPlan (Table 2 source of
    # truth) — both directions plus the two-way total (§9)
    plan = tr.layer_plan()
    dt = tr.opt.cfg.wire_dtype
    wire = plan.w2s_bytes_per_worker(dt)
    dense = plan.dense_bytes(dt)
    buf = plan.wire_layout(dt).total_nbytes
    s2w_wire = (plan.s2w_bytes_per_round(dt)
                if args.s2w != "identity" else 0)
    s2w_buf = (plan.wire_layout(dt, direction="s2w").total_nbytes
               if args.s2w != "identity" else 0)
    stages = plan.stage_plan(wire_stages=tr.opt.cfg.wire_stages).n_stages
    print(f"arch={cfg.name} params="
          f"{sum(p.size for p in jax.tree.leaves(state['x']))} "
          f"w2s_bytes/worker={wire} ({wire / dense:.3f} of dense) "
          f"wire_buffer={buf} ({buf / dense:.3f} of dense) "
          f"s2w_bytes/round={s2w_wire} s2w_wire_buffer={s2w_buf} "
          f"two_way_wire={buf + s2w_buf} "
          f"wire_stages={stages}")
    writer = None
    if args.metrics_out:
        writer = MetricsWriter(
            args.metrics_out,
            manifest=run_manifest(tcfg, None, extra={"arch": cfg.name}))
    sup = None
    if args.supervise:
        sup = Supervisor(
            SupervisorConfig(
                step_timeout_s=args.step_timeout,
                max_retries=args.max_retries,
                checkpoint_path=args.checkpoint,
                checkpoint_every=args.checkpoint_every),
            writer=writer, state_like=state)
        if writer is not None:
            writer.write("recovery", step=start, event="resume" if
                         args.resume else "start", attempt=0)
    resync_replayed = resync_full = last_lag = 0
    last_metrics: dict = {}
    aux = {"loss": float("nan")}   # resumed-at-end runs skip the loop
    t0 = time.time()
    try:
        i = start
        while i < args.steps:
            if faults is not None:
                # simulated power loss (crash:step=s): fresh runs only,
                # so the --resume run sails past the crash step
                faults.host_crash(i, start_step=start)
            if sup is not None:
                result, rs_state, rs_step = sup.run_step(
                    step_fn, state, data.batch_at(i), sched(i),
                    step=i, faults=faults)
                if result is None:
                    # checkpoint-reload recovery: rewind the loop to the
                    # last-good generation and re-step from there
                    state, i = rs_state, rs_step
                    print(f"recovered from {args.checkpoint} "
                          f"@ step {i}", flush=True)
                    continue
                state, aux = result
                sup.maybe_checkpoint(state, i)
            else:
                state, aux = step_fn(state, data.batch_at(i), sched(i))
            if "resync_replayed" in aux:
                resync_replayed += int(aux["resync_replayed"])
                resync_full += int(aux["resync_full"])
                last_lag = int(aux["version_lag_max"])
            if i % args.log_every == 0 or i == args.steps - 1:
                row = {"step": i, "loss": round(float(aux["loss"]), 4),
                       "radius": round(float(sched(i)), 5),
                       "wall_s": round(time.time() - t0, 1)}
                if "n_participants" in aux:
                    row["n_participants"] = int(aux["n_participants"])
                print(json.dumps(row), flush=True)
                if writer is not None:
                    last_metrics = aux["metrics"].host_floats()
                    if sup is not None:
                        last_metrics["supervisor/retries"] = float(
                            sup.retries)
                    writer.write("step", metrics=last_metrics, **row)
            i += 1
        if args.checkpoint:
            save_checkpoint(args.checkpoint, state, step=args.steps)
            print(f"saved {args.checkpoint}")
        spans = span_summary()
        ef_rows = _ef_summary_rows(last_metrics)
        _print_tables(spans, ef_rows)
        summary = {"final_loss": round(float(aux["loss"]), 4),
                   "resync_replayed": resync_replayed,
                   "resync_full": resync_full,
                   "version_lag_max": last_lag,
                   "supervisor_retries": sup.retries if sup else 0,
                   "supervisor_reloads": sup.reloads if sup else 0}
        # single greppable line: the chaos-soak CI job's assertion hook
        print("RESYNC_SUMMARY " + json.dumps(summary), flush=True)
        if writer is not None:
            for r in spans:
                writer.write("span", **r)
            writer.write("summary", spans=spans, ef_summary=ef_rows,
                         **summary)
    finally:
        if writer is not None:
            writer.close()
            print(f"metrics written to {args.metrics_out}")


if __name__ == "__main__":
    main()
