"""Pallas TPU kernel for Natural compression encode (Horvath et al. 2022).

Rounds bf16 values to the nearest power of two and emits the (exponent
code, sign) pair per element as uint8 planes — pure VPU bit manipulation,
elementwise-tiled in VMEM. The 8:1 sign bit-packing (which makes the wire
payload 9 bits/value) is a cheap reshape+dot done in ops.py after the
kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _natural_encode_kernel(x_ref, code_ref, sign_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...].astype(jnp.bfloat16),
                                        jnp.uint16)
    sign = (bits >> 15).astype(jnp.uint8)
    exp = ((bits >> 7) & 0xFF).astype(jnp.uint16)
    mant_hi = (bits >> 6) & 0x1
    exp_rounded = jnp.minimum(exp + mant_hi, 254)
    is_zero = (bits & 0x7FFF) == 0
    code_ref[...] = jnp.where(is_zero, jnp.uint16(0), exp_rounded).astype(jnp.uint8)
    sign_ref[...] = sign


def natural_encode(x: jax.Array, *, block_rows: int = 256,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Encode a [r, 128*k] bf16/f32 array -> (uint8 codes, uint8 signs).

    Rows must be a multiple of block_rows (ops.py pads/reshapes 1-D inputs).
    """
    r, cols = x.shape
    assert r % block_rows == 0, (x.shape, block_rows)
    grid = (r // block_rows,)
    spec = pl.BlockSpec((block_rows, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _natural_encode_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((r, cols), jnp.uint8),
                   jax.ShapeDtypeStruct((r, cols), jnp.uint8)),
        interpret=interpret,
    )(x)
