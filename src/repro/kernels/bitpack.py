"""Pallas TPU kernels for the wire bit-packing primitives (repro.wire).

Three bit-exact pack/unpack pairs, each with a pure-jnp reference that is
both the CPU execution path and the interpret-mode oracle:

  * ``pack_bits`` / ``unpack_bits`` — 1-bit plane packing (Natural sign
    planes): 8 consecutive {0,1} bytes -> one byte, LSB first.  Exactly
    the layout ops.py has always used, so Natural payloads stay
    bit-identical across backends.
  * ``narrow_encode`` / ``narrow_decode`` — width-byte integer encoding
    for TopK/ColumnTopK indices whose domain fits in 2 (uint16) or
    3 (uint24) bytes.  Plane-major little-endian layout: all low bytes,
    then the next plane(s) — each plane is a contiguous lane-aligned
    array, which keeps the TPU kernels pure VPU shift/mask ops.

Kernel notes (TPU adaptation):
  * the 1-bit kernels are lane-dim reductions/expansions by 8; both are
    expressed as one [1024, 128]-tiled matmul against a constant
    selector matrix built from iota (bit values <= 255 and power-of-two
    weights are exactly representable, and the dot runs with HIGHEST
    precision, so the arithmetic is exact).
  * the narrow kernels never touch the MXU: plane-major layout makes
    encode a shifted mask per grid step and decode a shift-accumulate
    over the plane grid dimension (int32 VPU ops; exact by
    construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_BITS_IN = _LANES * 8  # input lanes per packed 128-lane output tile


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------- jnp refs

def pack_bits_ref(bits01: jax.Array) -> jax.Array:
    """[8k] uint8 of {0,1} -> [k] uint8 bit-packed (LSB first)."""
    b = bits01.reshape(-1, 8).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights[None, :], axis=1, dtype=jnp.uint8)


def unpack_bits_ref(packed: jax.Array) -> jax.Array:
    """[k] uint8 -> [8k] uint8 of {0,1} (inverse of pack_bits_ref)."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return ((packed[:, None] >> shifts[None, :]) & 1).reshape(-1)


def narrow_encode_ref(idx: jax.Array, width: int) -> jax.Array:
    """int32 [k] in [0, 2^(8*width)) -> uint8 [width*k], plane-major
    little-endian (plane i holds byte i of every element)."""
    shifts = jnp.arange(width, dtype=jnp.int32)[:, None] * 8
    return ((idx[None, :] >> shifts) & 0xFF).astype(jnp.uint8).reshape(-1)


def narrow_decode_ref(b: jax.Array, width: int) -> jax.Array:
    """uint8 [width*k] plane-major -> int32 [k]."""
    planes = b.reshape(width, -1).astype(jnp.int32)
    shifts = jnp.arange(width, dtype=jnp.int32)[:, None] * 8
    return jnp.sum(planes << shifts, axis=0, dtype=jnp.int32)


# --------------------------------------------------------- 1-bit kernels

def _pack_bits_kernel(b_ref, o_ref):
    # [bm, 1024] {0,1} -> [bm, 128]: one dot against the selector matrix
    # W[l, t] = (l // 8 == t) * 2^(l % 8).  All values are integers
    # <= 255 with power-of-two weights, so the HIGHEST-precision dot is
    # exact.
    l = jax.lax.broadcasted_iota(jnp.int32, (_BITS_IN, _LANES), 0)
    t = jax.lax.broadcasted_iota(jnp.int32, (_BITS_IN, _LANES), 1)
    w = jnp.where(l // 8 == t, jnp.exp2((l % 8).astype(jnp.float32)), 0.0)
    acc = jnp.dot(b_ref[...].astype(jnp.float32), w,
                  preferred_element_type=jnp.float32,
                  precision=jax.lax.Precision.HIGHEST)
    o_ref[...] = acc.astype(jnp.uint8)


def _unpack_bits_kernel(p_ref, o_ref):
    # [bm, 128] bytes -> [bm, 1024] bits: replicate each byte over its 8
    # bit lanes (dot with a 0/1 selector), then extract bit (l % 8) with
    # exact f32 floor/mod arithmetic (bytes <= 255).
    t = jax.lax.broadcasted_iota(jnp.int32, (_LANES, _BITS_IN), 0)
    l = jax.lax.broadcasted_iota(jnp.int32, (_LANES, _BITS_IN), 1)
    rep = jnp.dot(p_ref[...].astype(jnp.float32),
                  jnp.where(l // 8 == t, 1.0, 0.0),
                  preferred_element_type=jnp.float32,
                  precision=jax.lax.Precision.HIGHEST)
    shift = jnp.exp2((jax.lax.broadcasted_iota(jnp.int32, (1, _BITS_IN), 1)
                      % 8).astype(jnp.float32))
    quot = jnp.floor(rep / shift)
    o_ref[...] = (quot - 2.0 * jnp.floor(quot / 2.0)).astype(jnp.uint8)


def _rows_2d(flat: jax.Array, lanes: int,
             max_block: int = 256) -> tuple[jax.Array, int]:
    """Zero-pad a flat array to [rows, lanes] with rows % block == 0."""
    n = flat.shape[0]
    pad = (-n) % lanes
    x = jnp.pad(flat, (0, pad)).reshape(-1, lanes)
    rows = x.shape[0]
    block = rows if rows < max_block else max_block
    rpad = (-rows) % block
    if rpad:
        x = jnp.pad(x, ((0, rpad), (0, 0)))
    return x, block


def pack_bits(bits01: jax.Array, use_pallas: str | bool = "auto",
              interpret: bool = False) -> jax.Array:
    """[8k] uint8 of {0,1} -> [k] uint8, LSB first (bit-exact pair with
    ``unpack_bits``; layout identical to the historical ops.py packer)."""
    n = bits01.shape[0]
    assert n % 8 == 0, n
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return pack_bits_ref(bits01)
    x, block = _rows_2d(bits01, _BITS_IN)
    out = pl.pallas_call(
        _pack_bits_kernel,
        grid=(x.shape[0] // block,),
        in_specs=[pl.BlockSpec((block, _BITS_IN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], _LANES), jnp.uint8),
        interpret=interpret,
    )(x)
    return out.reshape(-1)[:n // 8]


def unpack_bits(packed: jax.Array, use_pallas: str | bool = "auto",
                interpret: bool = False) -> jax.Array:
    """[k] uint8 -> [8k] uint8 of {0,1} (inverse of ``pack_bits``)."""
    k = packed.shape[0]
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return unpack_bits_ref(packed)
    x, block = _rows_2d(packed, _LANES)
    out = pl.pallas_call(
        _unpack_bits_kernel,
        grid=(x.shape[0] // block,),
        in_specs=[pl.BlockSpec((block, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, _BITS_IN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], _BITS_IN), jnp.uint8),
        interpret=interpret,
    )(x)
    return out.reshape(-1)[:8 * k]


# -------------------------------------------------------- narrow kernels

def _narrow_encode_kernel(i_ref, o_ref):
    # grid (planes, row blocks); plane j emits byte j of every element.
    j = pl.program_id(0)
    o_ref[...] = ((i_ref[...] >> (8 * j)) & 0xFF).astype(jnp.uint8)


def _narrow_decode_kernel(p_ref, o_ref, *, width: int):
    # grid (row blocks, planes); accumulate plane j << 8j into int32 out.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += p_ref[0].astype(jnp.int32) << (8 * j)


def narrow_width(domain: int) -> int:
    """Smallest byte width in {2, 3, 4} that indexes [0, domain)."""
    if domain <= 1 << 16:
        return 2
    if domain <= 1 << 24:
        return 3
    return 4


def narrow_encode(idx: jax.Array, width: int,
                  use_pallas: str | bool = "auto",
                  interpret: bool = False) -> jax.Array:
    """int32 [k] -> uint8 [width*k], plane-major little-endian.

    Values must lie in [0, 2^(8*width)); bit-exact pair with
    ``narrow_decode``. width == 4 round-trips any non-negative int32."""
    k = idx.shape[0]
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return narrow_encode_ref(idx, width)
    x, block = _rows_2d(idx, _LANES)
    rows = x.shape[0]
    out = pl.pallas_call(
        _narrow_encode_kernel,
        grid=(width, rows // block),
        in_specs=[pl.BlockSpec((1, block, _LANES), lambda j, i: (0, i, 0))],
        out_specs=pl.BlockSpec((1, block, _LANES), lambda j, i: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((width, rows, _LANES), jnp.uint8),
        interpret=interpret,
    )(x[None])
    # plane-major: [width, rows*lanes] -> drop per-plane padding -> flat
    return out.reshape(width, -1)[:, :k].reshape(-1)


def narrow_decode(b: jax.Array, width: int,
                  use_pallas: str | bool = "auto",
                  interpret: bool = False) -> jax.Array:
    """uint8 [width*k] plane-major -> int32 [k]."""
    assert b.shape[0] % width == 0, (b.shape, width)
    k = b.shape[0] // width
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return narrow_decode_ref(b, width)
    pad = (-k) % _LANES
    planes = jnp.pad(b.reshape(width, k), ((0, 0), (0, pad)))
    planes = planes.reshape(width, -1, _LANES)
    rows = planes.shape[1]
    block = rows if rows < 256 else 256
    rpad = (-rows) % block
    if rpad:
        planes = jnp.pad(planes, ((0, 0), (0, rpad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_narrow_decode_kernel, width=width),
        grid=(planes.shape[1] // block, width),
        in_specs=[pl.BlockSpec((1, block, _LANES), lambda i, j: (j, i, 0))],
        out_specs=pl.BlockSpec((block, _LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((planes.shape[1], _LANES), jnp.int32),
        interpret=interpret,
    )(planes)
    return out.reshape(-1)[:k]
