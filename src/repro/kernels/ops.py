"""Jit-ready wrappers around the Pallas kernels with padding + CPU fallback.

``use_pallas='auto'`` selects the Pallas path on TPU backends and the jnp
reference (the oracle in ref.py) on CPU, where Pallas only runs in
interpret mode (kept for tests, too slow for the training loop).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import ref
from .bitpack import pack_bits, unpack_bits
from .natural_pack import natural_encode
from .newton_schulz import (fused_ns_feasible, ns_iteration_fused,
                            ns_iteration_pallas)

NS_COEFFS = ref.NS_COEFFS


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


NS_KERNEL_NAMES = ("_ns_fused_kernel", "_fused_matmul_kernel")


def count_ns_dispatches(jaxpr, names=NS_KERNEL_NAMES) -> int:
    """Recursively count NS pallas_call equations (fused or chained) in
    a jaxpr — the traced dispatch count the bucketing regression test
    and benchmarks/ns_bench.py both pin. Counts at trace level, so it
    works on any backend (nothing is lowered or executed)."""
    import jax.extend.core as jex

    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            kname = getattr(eqn.params.get("name_and_src_info"), "name",
                            None) or str(eqn.params.get("name", ""))
            if any(s in kname for s in names):
                n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for vi in vs:                 # lax.cond/switch keep a tuple
                if isinstance(vi, jex.ClosedJaxpr):
                    n += count_ns_dispatches(vi.jaxpr, names)
                elif hasattr(vi, "eqns"):
                    n += count_ns_dispatches(vi, names)
    return n


def _pad_to(x: jax.Array, mult: int) -> tuple[jax.Array, tuple[int, int]]:
    m, n = x.shape
    pm = (-m) % mult
    pn = (-n) % mult
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, (m, n)


def newton_schulz(g: jax.Array, steps: int = 5, coeffs=NS_COEFFS,
                  eps: float = 1e-7, use_pallas: str | bool = "auto",
                  block: int = 128, interpret: bool = False,
                  fused: str | bool = "auto") -> jax.Array:
    """Orthogonalise ``g`` (approximate UV^T of its SVD).

    Pallas path: pad to MXU-aligned multiples of ``block``, run the quintic
    iteration with blocked VMEM matmuls, then slice back. Zero padding is
    exact (padded rows/cols remain zero through X' = aX + (bA + cA^2)X).

    ``fused='auto'`` runs each iteration as ONE fused pallas_call (gram and
    poly in VMEM scratch) whenever the [m, m] gram fits the VMEM budget,
    falling back to the three-call chain; ``fused=False`` keeps the
    three-call chain unconditionally (the pre-fusion A/B reference).
    """
    if g.ndim != 2:
        raise ValueError("newton_schulz expects 2-D input")
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.newton_schulz_ref(g, steps=steps, coeffs=coeffs, eps=eps)
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g
    x = x / (jnp.linalg.norm(x.astype(jnp.float32)) + eps).astype(x.dtype)
    x, (m, n) = _pad_to(x, block)
    if fused == "auto":
        fused = fused_ns_feasible(x.shape[0], block, x.dtype.itemsize)
    for _ in range(steps):
        if fused:
            x = ns_iteration_fused(x[None], coeffs, block_m=block,
                                   block_n=block, interpret=interpret)[0]
        else:
            x = ns_iteration_pallas(x, coeffs, block=block,
                                    interpret=interpret)
    x = x[:m, :n]
    return x.T if transpose else x


def newton_schulz_batched(g: jax.Array, steps: int = 5, coeffs=NS_COEFFS,
                          eps: float = 1e-7,
                          use_pallas: str | bool = "auto", block: int = 128,
                          interpret: bool = False,
                          fused: str | bool = "auto",
                          mesh=None, pspec=None) -> jax.Array:
    """Orthogonalise a ``[B, m, n]`` stack of independent slices.

    The batched entry point behind shape bucketing (DESIGN.md §7): one
    dispatch chain of ``steps`` fused kernels for the whole stack. Callers
    canonicalise orientation (m <= n) before stacking — there is no
    per-slice transpose handling here. The jnp path is the bit-matching
    ``newton_schulz_batched_ref``; the Pallas path pads every slice to
    ``block`` multiples (zero padding is exact, as in ``newton_schulz``)
    and falls back to a vmapped three-call chain when the [m, m] gram
    exceeds the fused kernel's VMEM budget (or ``fused=False``).

    ``mesh``/``pspec`` make the chain sharding-aware (the
    ``ns_bucket_pspec`` of the stack, threaded down from the bucketed
    phase-5 dispatch): on the jnp path every iterate is pinned with
    ``with_sharding_constraint`` so the partitioner batch/TP-shards the
    chain instead of replicating it; on the Pallas path the fused kernel
    runs under ``shard_map`` over the batch axes of ``pspec``, each
    device dispatching its local ``[B/shards, m, n]`` sub-batch
    (``fused_ns_feasible`` gated on the per-device sub-batch). Both are
    value-identities — sharding never changes the math of a slice.
    """
    if g.ndim != 3:
        raise ValueError("newton_schulz_batched expects [B, m, n]")
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        hook = None
        if mesh is not None and pspec is not None \
                and isinstance(mesh, jax.sharding.Mesh):
            sharding = jax.sharding.NamedSharding(mesh, pspec)
            hook = lambda x: jax.lax.with_sharding_constraint(x, sharding)
        return ref.newton_schulz_batched_ref(g, steps=steps, coeffs=coeffs,
                                             eps=eps, hook=hook)

    def chain(x):
        # per-shard body: normalise per slice, pad to block multiples,
        # run the iteration chain, slice back. Under shard_map x is the
        # local [B/shards, m, n] sub-batch and the VMEM feasibility gate
        # sees exactly what one device will dispatch.
        nrm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)),
                               axis=(-2, -1), keepdims=True))
        x = x / (nrm + eps).astype(x.dtype)
        m, n = x.shape[1:]
        pm, pn = (-m) % block, (-n) % block
        if pm or pn:
            x = jnp.pad(x, ((0, 0), (0, pm), (0, pn)))
        use_fused = fused
        if use_fused == "auto":
            use_fused = fused_ns_feasible(x.shape[1], block, x.dtype.itemsize)
        for _ in range(steps):
            if use_fused:
                x = ns_iteration_fused(x, coeffs, block_m=block,
                                       block_n=block, interpret=interpret)
            else:
                x = jax.vmap(lambda s: ns_iteration_pallas(
                    s, coeffs, block=block, interpret=interpret))(x)
        return x[:, :m, :n]

    if mesh is not None and pspec is not None \
            and isinstance(mesh, jax.sharding.Mesh) and len(pspec) \
            and pspec[0] is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        lead = pspec[0]
        axes = (lead,) if isinstance(lead, str) else tuple(lead)
        shards = 1
        for a in axes:
            shards *= mesh.shape[a]
        if shards > 1 and g.shape[0] % shards == 0:
            # batch axes only: each shard needs its slices whole (the
            # fused kernel grams over the full [m, n] slice locally), so
            # any trailing model spec stays outside the shard_map — the
            # kernel is batch-parallel, TP applies to the jnp path.
            spec = P(lead, None, None)
            return shard_map(chain, mesh=mesh, in_specs=(spec,),
                             out_specs=spec, check_rep=False)(g)
    return chain(g)


def natural_compress(x: jax.Array, use_pallas: str | bool = "auto",
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Natural-compress any-shaped array -> (codes uint8 [N], packed signs
    uint8 [ceil(N/8)]). The wire payload is 9 bits/value."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        lanes = 128
        pad = (-n) % (lanes * 8)
        padded = jnp.pad(flat, (0, pad)).reshape(-1, lanes)
        rows = padded.shape[0]
        block_rows = rows if rows < 256 else 256
        rpad = (-rows) % block_rows
        if rpad:
            padded = jnp.pad(padded, ((0, rpad), (0, 0)))
        code, sign = natural_encode(padded, block_rows=block_rows,
                                    interpret=interpret)
        code = code.reshape(-1)[:n + pad]
        sign = sign.reshape(-1)[:n + pad]
    else:
        pad = (-n) % 8
        flat_p = jnp.pad(flat, (0, pad))
        code, sign = ref.natural_compress_ref(flat_p)
    return code[:n], pack_bits(jnp.pad(sign[:n], (0, (-n) % 8)),
                               use_pallas=use_pallas, interpret=interpret)


def natural_decompress(code: jax.Array, packed_sign: jax.Array,
                       shape: tuple[int, ...], dtype=jnp.bfloat16,
                       use_pallas: str | bool = "auto",
                       interpret: bool = False) -> jax.Array:
    n = code.shape[0]
    sign = unpack_bits(packed_sign, use_pallas=use_pallas,
                       interpret=interpret)[:n]
    vals = ref.natural_decompress_ref(code, sign)
    return vals.reshape(shape).astype(dtype)
