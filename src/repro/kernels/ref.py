"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel is validated
against these references over shape/dtype sweeps in tests/test_kernels.py
(interpret=True on CPU), and they double as the CPU execution path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Jordan et al. (2024) quintic Newton-Schulz coefficients.
NS_COEFFS = (3.4445, -4.7750, 2.0315)


def fused_matmul_ref(a: jax.Array, b: jax.Array, c: jax.Array | None,
                     alpha: float = 1.0, beta: float = 1.0) -> jax.Array:
    """out = alpha * c + beta * (a @ b), f32 accumulation, output dtype f32."""
    out = beta * jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
    if c is not None:
        out = out + alpha * c.astype(jnp.float32)
    return out


def ns_iteration_ref(x: jax.Array, coeffs=NS_COEFFS) -> jax.Array:
    """One quintic Newton-Schulz iteration: X' = aX + (bA + cA^2) X, A = XX^T."""
    a, b, c = coeffs
    xf = x.astype(jnp.float32)
    gram = xf @ xf.T
    poly = b * gram + c * (gram @ gram)
    return (a * xf + poly @ xf).astype(x.dtype)


def newton_schulz_ref(g: jax.Array, steps: int = 5, coeffs=NS_COEFFS,
                      eps: float = 1e-7) -> jax.Array:
    """Approximate UV^T of the SVD of g (orthogonalisation), jnp oracle.

    Operates on the transposed matrix when rows > cols so the gram matrix
    is built on the small side, matching the Muon reference implementation.
    """
    if g.ndim != 2:
        raise ValueError("newton_schulz_ref expects a 2-D matrix")
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g
    x = x / (jnp.linalg.norm(x.astype(jnp.float32)) + eps).astype(x.dtype)
    for _ in range(steps):
        x = ns_iteration_ref(x, coeffs)
    return x.T if transpose else x


def ns_iteration_batched_ref(x: jax.Array, coeffs=NS_COEFFS) -> jax.Array:
    """Batched quintic NS iteration over a [B, m, n] slice stack.

    Native batched matmuls — traces to exactly the dot_generals that
    ``jax.vmap(ns_iteration_ref)`` produces, so it stays bit-identical to
    the per-slice oracle (asserted in tests/test_ns_bucketing.py).
    """
    a, b, c = coeffs
    xf = x.astype(jnp.float32)
    gram = xf @ jnp.swapaxes(xf, -1, -2)
    poly = b * gram + c * (gram @ gram)
    return (a * xf + poly @ xf).astype(x.dtype)


def newton_schulz_batched_ref(g: jax.Array, steps: int = 5,
                              coeffs=NS_COEFFS,
                              eps: float = 1e-7,
                              hook=None) -> jax.Array:
    """Batched orthogonalisation oracle over [B, m, n] slice stacks.

    No transpose handling: the bucketing layer (repro.dist.bucketing)
    canonicalises every slice to m <= n before stacking. Per-slice f32
    Frobenius normalisation matches ``newton_schulz_ref`` bit-for-bit.

    ``hook``, when given, is a value-identity applied to the iterate
    after normalisation and after every iteration — the sharding layer
    (kernels/ops.py) threads ``with_sharding_constraint`` through the
    chain with it, so the partitioner keeps the stack sharded instead of
    replicating the whole chain. ``hook=None`` leaves the oracle
    untouched.
    """
    if g.ndim != 3:
        raise ValueError("newton_schulz_batched_ref expects [B, m, n]")
    nrm = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32)),
                           axis=(-2, -1), keepdims=True))
    x = g / (nrm + eps).astype(g.dtype)
    if hook is not None:
        x = hook(x)
    for _ in range(steps):
        x = ns_iteration_batched_ref(x, coeffs)
        if hook is not None:
            x = hook(x)
    return x


def natural_compress_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Deterministic natural compression of bf16 values: round to the
    nearest power of two. Returns (exp_code uint8, sign uint8 in {0,1}).

    bf16 layout: 1 sign | 8 exponent | 7 mantissa. Rounding to the nearest
    power of two increments the exponent when the mantissa >= 0.5 (top
    mantissa bit set). Relative error <= 1/3 => contractive with
    alpha = 1 - 1/9 = 8/9 in any elementwise norm.
    Zero maps to code 0; inf/nan clamp to code 254.
    """
    xb = x.astype(jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(xb, jnp.uint16)
    sign = (bits >> 15).astype(jnp.uint8)
    exp = ((bits >> 7) & 0xFF).astype(jnp.uint16)
    mant_hi = (bits >> 6) & 0x1
    exp_rounded = jnp.minimum(exp + mant_hi, 254).astype(jnp.uint8)
    is_zero = (bits & 0x7FFF) == 0
    code = jnp.where(is_zero, jnp.uint8(0), exp_rounded)
    return code, sign


def natural_decompress_ref(code: jax.Array, sign: jax.Array) -> jax.Array:
    """Inverse of natural_compress_ref -> bf16 powers of two."""
    bits = (sign.astype(jnp.uint16) << 15) | (code.astype(jnp.uint16) << 7)
    bits = jnp.where(code == 0, sign.astype(jnp.uint16) << 15, bits)
    return jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.bfloat16)
