# Pallas TPU kernels for EF21-Muon's compute hot-spots:
#  - newton_schulz: blocked-matmul quintic NS orthogonalisation (Muon LMO)
#  - natural_pack: Natural-compression bit-manipulation encode
#  - bitpack: wire bit-packing primitives (1-bit sign planes, narrow
#    uint16/uint24 index encoding) shared by ops.py and repro.wire
# Each has a pure-jnp oracle (ref.py / bitpack.py refs) and a padded jit
# wrapper with a CPU fallback.
from .bitpack import (narrow_decode, narrow_encode, narrow_width, pack_bits,
                      unpack_bits)
from .newton_schulz import fused_ns_feasible
from .ops import (NS_COEFFS, count_ns_dispatches, natural_compress,
                  natural_decompress, newton_schulz, newton_schulz_batched)

__all__ = ["NS_COEFFS", "natural_compress", "natural_decompress",
           "newton_schulz", "newton_schulz_batched", "fused_ns_feasible",
           "count_ns_dispatches",
           "pack_bits", "unpack_bits", "narrow_encode", "narrow_decode",
           "narrow_width"]
