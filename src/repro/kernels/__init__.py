# Pallas TPU kernels for EF21-Muon's compute hot-spots:
#  - newton_schulz: blocked-matmul quintic NS orthogonalisation (Muon LMO)
#  - natural_pack: Natural-compression bit-manipulation encode
# Each has a pure-jnp oracle in ref.py and a padded jit wrapper in ops.py.
from .ops import (NS_COEFFS, natural_compress, natural_decompress,
                  newton_schulz)

__all__ = ["NS_COEFFS", "natural_compress", "natural_decompress",
           "newton_schulz"]
