"""Pallas TPU kernels for Newton-Schulz orthogonalisation (Muon's hot spot).

Two generations of kernels:

  * ``fused_matmul``: ``out = alpha * C + beta * (A @ B)`` — the original
    blocked-matmul workhorse. One NS iteration is three chained calls:
        gram = X @ X^T                       (fused_matmul(X, X^T))
        poly = b*gram + c*(gram @ gram)      (fused_matmul(gram, gram, C=gram, alpha=b, beta=c))
        X'   = a*X + poly @ X                (fused_matmul(poly, X, C=X, alpha=a))
    The ``gram``/``poly`` intermediates round-trip through HBM between the
    three pallas_calls.

  * ``ns_iteration_fused``: ONE pallas_call per NS iteration over a whole
    ``[B, m, n]`` stack of independent slices (DESIGN.md §7). The gram and
    the quintic polynomial live in ``[m, m]`` f32 VMEM scratch for the
    entire iteration — they never touch HBM — and the batch is a parallel
    grid dimension, so a shape bucket of identically-shaped layers is one
    dispatch chain of ``ns_steps`` kernels instead of ``3 * ns_steps``
    kernels *per layer*.

Design notes (TPU adaptation):
  * blocks default to (128, 128, 128): MXU-aligned on all three matmul dims;
    the K-dim is the innermost ("arbitrary") grid axis so the output block
    revisits stay in VMEM between K steps.
  * accumulation always f32 in a VMEM scratch buffer, cast to the output
    dtype on the final K step (bf16-safe for 5 chained iterations).
  * shapes are padded to block multiples by the ops.py wrapper; zero padding
    is exact for NS (padded rows/cols stay exactly zero through the
    polynomial), verified in tests.
  * the fused kernel accumulates only the upper-triangular tiles of the
    symmetric gram ``X X^T`` and mirrors the lower triangle once per
    iteration — T(T+1)/2 instead of T^2 tile matmuls on the gram phase.
  * the fused kernel needs ``2 * 4 * m^2`` bytes of VMEM scratch;
    ``fused_ns_feasible`` gates it, the ops.py wrapper falls back to the
    three-call chain for slices whose gram does not fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions: TPUCompilerParams (<=0.4.x) -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _fused_matmul_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, nk: int,
                         alpha: float, beta: float, has_c: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        acc = beta * acc_ref[...]
        if has_c:
            acc = acc + alpha * c_ref[...].astype(jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


def fused_matmul(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
                 alpha: float = 1.0, beta: float = 1.0,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 out_dtype=None, interpret: bool = False) -> jax.Array:
    """``alpha * c + beta * (a @ b)`` with blocked VMEM tiling.

    Requires m % block_m == n % block_n == k % block_k == 0 (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (a.shape, b.shape, block_m, block_n, block_k)
    if _CompilerParams is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams in this jax version; the Pallas "
            "Newton-Schulz path cannot be configured — pass "
            "use_pallas=False (jnp reference) or update jax.")
    out_dtype = out_dtype or a.dtype
    nk = k // block_k
    has_c = c is not None
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    if has_c:
        in_specs.append(pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)))
        operands.append(c)
    else:
        # dummy scalar-shaped operand so the kernel signature is fixed
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
        operands.append(jnp.zeros((1, 1), dtype=out_dtype))
    kernel = functools.partial(_fused_matmul_kernel, nk=nk, alpha=alpha,
                               beta=beta, has_c=has_c)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


def ns_iteration_pallas(x: jax.Array, coeffs, *, block: int = 128,
                        interpret: bool = False) -> jax.Array:
    """One quintic NS iteration via three fused_matmul calls.

    x: [m, n] with both dims multiples of ``block`` (pad upstream).
    """
    a, b, c = coeffs
    xt = x.T
    gram = fused_matmul(x, xt, block_m=block, block_n=block, block_k=block,
                        out_dtype=jnp.float32, interpret=interpret)
    poly = fused_matmul(gram, gram, c=gram, alpha=b, beta=c,
                        block_m=block, block_n=block, block_k=block,
                        out_dtype=jnp.float32, interpret=interpret)
    out = fused_matmul(poly, x, c=x, alpha=a, beta=1.0,
                       block_m=block, block_n=block, block_k=block,
                       out_dtype=x.dtype, interpret=interpret)
    return out


# ------------------------------------------------------- fused NS iteration

# VMEM budget for the fused kernel (of ~16 MB/core): 2 f32 [m, m] scratch
# buffers + double-buffered in/out [m, block_n] tiles must fit.
_FUSED_VMEM_BUDGET = 12 * 1024 * 1024

_CONTRACT_LAST = (((1,), (1,)), ((), ()))   # A @ B^T on [p, k] x [q, k]


def fused_ns_vmem_bytes(m: int, block_n: int, itemsize: int) -> int:
    """VMEM bytes the fused iteration kernel needs for ``[*, m, n]``
    slices: gram + poly scratch (f32) plus double-buffered X/X' tiles."""
    scratch = 2 * 4 * m * m
    tiles = 2 * 2 * m * block_n * max(itemsize, 4)
    return scratch + tiles


def fused_ns_feasible(m: int, block_n: int = 128, itemsize: int = 4) -> bool:
    """Whether the whole [m, m] gram fits the fused kernel's VMEM budget
    (the ops.py wrapper falls back to the three-call chain otherwise)."""
    return fused_ns_vmem_bytes(m, block_n, itemsize) <= _FUSED_VMEM_BUDGET


def _ns_fused_kernel(x_ref, o_ref, gram_ref, poly_ref, *, nj: int, nmt: int,
                     block_m: int, a: float, b: float, c: float):
    """One grid step of the fused iteration. Grid: (batch, phase, j).

    phase 0 sweeps the n-tiles of X accumulating the upper-triangular
    tiles of gram = X X^T in VMEM scratch; on the last n-tile it mirrors
    the lower triangle and evaluates poly = b*gram + c*gram^2 into the
    second scratch. phase 1 sweeps the n-tiles again emitting
    X' = a*X + poly @ X. gram/poly never leave VMEM.
    """
    ph = pl.program_id(1)
    j = pl.program_id(2)
    x = x_ref[0]                               # [m, block_n]

    @pl.when((ph == 0) & (j == 0))
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    @pl.when(ph == 0)
    def _gram():
        # upper-triangular tile accumulation: gram is symmetric, so the
        # T(T-1)/2 sub-diagonal tile matmuls are redundant and skipped.
        for mi in range(nmt):
            ri = slice(mi * block_m, (mi + 1) * block_m)
            xi = x[ri, :]
            for mj in range(mi, nmt):
                rj = slice(mj * block_m, (mj + 1) * block_m)
                gram_ref[ri, rj] += jax.lax.dot_general(
                    xi, x[rj, :], _CONTRACT_LAST,
                    preferred_element_type=jnp.float32)
        # the out block is flushed each j-step either way; write the aX
        # term so phase-0 flushes are deterministic (phase 1 overwrites).
        o_ref[0] = (a * x.astype(jnp.float32)).astype(o_ref.dtype)

    @pl.when((ph == 0) & (j == nj - 1))
    def _poly():
        for mi in range(nmt):
            ri = slice(mi * block_m, (mi + 1) * block_m)
            for mj in range(mi + 1, nmt):
                rj = slice(mj * block_m, (mj + 1) * block_m)
                gram_ref[rj, ri] = gram_ref[ri, rj].T
        g = gram_ref[...]
        poly_ref[...] = b * g + c * jnp.dot(
            g, g, preferred_element_type=jnp.float32)

    @pl.when(ph == 1)
    def _update():
        xf = x.astype(jnp.float32)
        o_ref[0] = (a * xf + jnp.dot(
            poly_ref[...], xf,
            preferred_element_type=jnp.float32)).astype(o_ref.dtype)


def ns_iteration_fused(x: jax.Array, coeffs, *, block_m: int = 128,
                       block_n: int = 128,
                       interpret: bool = False) -> jax.Array:
    """One quintic NS iteration for a ``[B, m, n]`` stack in ONE pallas_call.

    m % block_m == n % block_n == 0 (pad upstream); gram/poly stay in VMEM
    (caller gates on ``fused_ns_feasible(m, block_n)``). The batch is a
    parallel grid dim; phases and n-tiles are sequential, so the scratch
    accumulator is re-initialised per batch element.
    """
    bsz, m, n = x.shape
    if m % block_m or n % block_n:
        raise ValueError(
            f"ns_iteration_fused needs block-aligned slices, got {x.shape} "
            f"for blocks ({block_m}, {block_n}) — pad upstream (ops.py)")
    if _CompilerParams is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams in this jax version; the Pallas "
            "Newton-Schulz path cannot be configured — pass "
            "use_pallas=False (jnp reference) or update jax.")
    a, b, c = coeffs
    nj = n // block_n
    kernel = functools.partial(
        _ns_fused_kernel, nj=nj, nmt=m // block_m, block_m=block_m,
        a=float(a), b=float(b), c=float(c))
    spec = pl.BlockSpec((1, m, block_n), lambda bi, ph, j: (bi, 0, j))
    return pl.pallas_call(
        kernel,
        grid=(bsz, 2, nj),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32),
                        pltpu.VMEM((m, m), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x)
