"""Pallas TPU kernels for Newton-Schulz orthogonalisation (Muon's hot spot).

Two kernels built on one blocked-matmul body with explicit BlockSpec VMEM
tiling and an f32 VMEM accumulator:

  * ``fused_matmul``: ``out = alpha * C + beta * (A @ B)`` — the workhorse.
    One NS iteration is three chained calls:
        gram = X @ X^T                       (fused_matmul(X, X^T))
        poly = b*gram + c*(gram @ gram)      (fused_matmul(gram, gram, C=gram, alpha=b, beta=c))
        X'   = a*X + poly @ X                (fused_matmul(poly, X, C=X, alpha=a))

Design notes (TPU adaptation):
  * blocks default to (128, 128, 128): MXU-aligned on all three matmul dims;
    the K-dim is the innermost ("arbitrary") grid axis so the output block
    revisits stay in VMEM between K steps.
  * accumulation always f32 in a VMEM scratch buffer, cast to the output
    dtype on the final K step (bf16-safe for 5 chained iterations).
  * shapes are padded to block multiples by the ops.py wrapper; zero padding
    is exact for NS (padded rows/cols stay exactly zero through the
    polynomial), verified in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions: TPUCompilerParams (<=0.4.x) -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _fused_matmul_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, nk: int,
                         alpha: float, beta: float, has_c: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        acc = beta * acc_ref[...]
        if has_c:
            acc = acc + alpha * c_ref[...].astype(jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


def fused_matmul(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
                 alpha: float = 1.0, beta: float = 1.0,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 out_dtype=None, interpret: bool = False) -> jax.Array:
    """``alpha * c + beta * (a @ b)`` with blocked VMEM tiling.

    Requires m % block_m == n % block_n == k % block_k == 0 (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (a.shape, b.shape, block_m, block_n, block_k)
    if _CompilerParams is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams in this jax version; the Pallas "
            "Newton-Schulz path cannot be configured — pass "
            "use_pallas=False (jnp reference) or update jax.")
    out_dtype = out_dtype or a.dtype
    nk = k // block_k
    has_c = c is not None
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    if has_c:
        in_specs.append(pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)))
        operands.append(c)
    else:
        # dummy scalar-shaped operand so the kernel signature is fixed
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
        operands.append(jnp.zeros((1, 1), dtype=out_dtype))
    kernel = functools.partial(_fused_matmul_kernel, nk=nk, alpha=alpha,
                               beta=beta, has_c=has_c)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


def ns_iteration_pallas(x: jax.Array, coeffs, *, block: int = 128,
                        interpret: bool = False) -> jax.Array:
    """One quintic NS iteration via three fused_matmul calls.

    x: [m, n] with both dims multiples of ``block`` (pad upstream).
    """
    a, b, c = coeffs
    xt = x.T
    gram = fused_matmul(x, xt, block_m=block, block_n=block, block_k=block,
                        out_dtype=jnp.float32, interpret=interpret)
    poly = fused_matmul(gram, gram, c=gram, alpha=b, beta=c,
                        block_m=block, block_n=block, block_k=block,
                        out_dtype=jnp.float32, interpret=interpret)
    out = fused_matmul(poly, x, c=x, alpha=a, beta=1.0,
                       block_m=block, block_n=block, block_k=block,
                       out_dtype=x.dtype, interpret=interpret)
    return out
